//! `hisafe balance` — a fail-over load balancer in front of several
//! `hisafe serve` hosts, making the cluster look like one server.
//!
//! The balancer speaks the *same* wire protocol on both sides: clients
//! talk to it with an ordinary [`ServiceClient`], and it talks to every
//! backend host with one. No protocol fork, no balancer-specific
//! messages — the cluster primitive is the `SessionSnapshot` /
//! `SessionRestore` pair that PR 6 added to [`super::proto`]. Codec
//! negotiation (JSON vs the v2 binary framing, [`super::binary`])
//! happens independently per connection on each side: a JSON client can
//! front binary backends and vice versa, because the balancer re-encodes
//! every forwarded request on its own backend connections.
//!
//! ```text
//!  tenants ──▶ hisafe balance ──▶ hisafe serve  (host 0: K shards)
//!                   │       └───▶ hisafe serve  (host 1: K shards)
//!                   │
//!             session table: client sid → (host, backend sid, snapshot)
//! ```
//!
//! **Placement.** Tenants are placed on hosts by the same rendezvous
//! hash the frontend uses for shards ([`rendezvous_rank`] over
//! [`tenant_key`]), filtered to live hosts — so any number of balancer
//! processes pointed at the same host list agree on placement without
//! coordinating.
//!
//! **Fail-over.** The balancer tracks, for every session, the exact
//! [`SessionSnapshot`] a restore needs: the open-time `(cfg, d, seed,
//! qos)` plus a `rounds` counter incremented **only after a vote has
//! been returned to the client**. When a backend call fails with a
//! transport error, the host is marked dead and the session is replayed
//! onto the next-ranked live host via `SessionRestore`; the in-flight
//! request is then retried there. Two deterministic consequences:
//!
//! * A round whose reply was *lost* (host died after executing it) is
//!   simply re-run on the new host — same seed-derived triples, same
//!   round index, bit-identical vote. Duplicated work, never duplicated
//!   or skipped rounds, exactly because `rounds` counts client-observed
//!   votes, not submissions.
//! * Votes across a fail-over are bit-identical to an uninterrupted
//!   run (`run_sync` ≡ single host ≡ mid-sweep host kill), pinned by
//!   the tests below and the three-process CI smoke.
//!
//! Restores are serialized by a dedicated lock so concurrent requests
//! hitting the same dead host perform one restore, not a thundering
//! herd of duplicates.
//!
//! **Health & re-join.** A background thread pings every host
//! (`StatsQuery` on the whole frontend) each `health_every`; a dead
//! host that answers again is revived and returns to the placement
//! rotation. A dead→alive transition additionally triggers
//! **reconciliation** ([`BalCore::reconcile_host`]): the balancer
//! sweeps the revived host's live sessions (`SessionList`) and, under
//! the restore lock, (a) re-places every table entry stranded there —
//! the host restarted, so its backend session is gone and the entry
//! would otherwise answer every request with a stale `UnknownSession`
//! denial forever — and (b) discards every backend session the table
//! no longer claims (`SessionDiscard`, *not* `SessionClose`: the
//! session's history is owned by its restored twin elsewhere, and
//! close would fold the stale copy's counters into the host's
//! aggregate, double-counting those rounds in merged cluster stats).
//!
//! **Rebuild.** A *restarted balancer* does not start blind: before
//! accepting clients, [`Balancer::serve`] sweeps every reachable host
//! with `SessionList` and repopulates its session table from the
//! host-side snapshots (fresh client ids; clients re-discover theirs
//! by matching `(cfg, d, seed)` in the balancer's own `SessionList`
//! reply, which is answered locally from the table).
//!
//! **Concurrency.** One persistent backend connection per host (a
//! mutex serializes requests to that host — matching the per-host
//! parallelism the backends' shard locks provide). Client connections
//! ride the same **bounded connection-worker pump** the backends use
//! ([`super::server::serve_frames`]): the accept loop parks every
//! connection in a registry and a fixed worker pool sweeps them, so a
//! thousand connected-but-quiet tenants cost registry entries, not OS
//! threads — the old thread-per-client proxy is gone.
//!
//! **Shutdown.** A client `Shutdown` is acked, fanned out to every
//! live backend, and then stops the balancer itself — one command
//! winds down the whole cluster (the CI smoke asserts every process
//! exits cleanly).

use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::engine::{AdmissionError, SessionId, SessionSnapshot};
use crate::metrics::AdmissionStats;

use super::error::Error;
use super::frontend::{rendezvous_rank, tenant_key};
use super::proto::{
    AdmissionReply, Codec, ProtoError, Request, Response, SessionListReply, SnapshotReply,
    StatsReply,
};
use super::server::{serve_frames, FrameHandler, ServiceClient, DEFAULT_WORKERS};

/// One backend host: its address, liveness flag, the codec its
/// connections ask for, and the persistent connection requests
/// multiplex over.
struct HostHandle {
    addr: String,
    alive: AtomicBool,
    want: Codec,
    conn: Mutex<Option<ServiceClient>>,
}

impl HostHandle {
    fn new(addr: String, want: Codec) -> HostHandle {
        HostHandle { addr, alive: AtomicBool::new(true), want, conn: Mutex::new(None) }
    }

    /// One request/reply against this host, (re)connecting lazily (each
    /// fresh connection renegotiates its codec from scratch — a restore
    /// after fail-over carries the ask like any open does). A transport
    /// failure marks the host dead and drops the connection; a success
    /// (including a typed denial) marks it alive — which is how the
    /// health ping revives hosts.
    fn call(&self, req: &Request) -> Result<Response, Error> {
        let mut guard = self.conn.lock().unwrap_or_else(|p| p.into_inner());
        if guard.is_none() {
            match ServiceClient::connect_with_codec(&self.addr, self.want) {
                Ok(c) => *guard = Some(c),
                Err(e) => {
                    self.alive.store(false, Ordering::SeqCst);
                    return Err(Error::Io(e));
                }
            }
        }
        match guard.as_mut().expect("connected above").call(req) {
            Ok(resp) => {
                self.alive.store(true, Ordering::SeqCst);
                Ok(resp)
            }
            Err(e @ Error::Io(_)) => {
                *guard = None;
                self.alive.store(false, Ordering::SeqCst);
                Err(e)
            }
            Err(e @ Error::Proto(_)) => {
                // Framing desync: the connection is unusable but the
                // host answered — drop the conn, keep the host.
                *guard = None;
                Err(e)
            }
            Err(e) => Err(e),
        }
    }
}

/// What the balancer remembers per client session: where it lives and
/// the snapshot that re-creates it anywhere.
struct BalSession {
    host: usize,
    backend_sid: SessionId,
    snap: SessionSnapshot,
}

/// The shared balancer state every client-connection thread routes
/// through.
struct BalCore {
    hosts: Vec<HostHandle>,
    sessions: Mutex<BTreeMap<SessionId, BalSession>>,
    /// Serializes fail-over restores (see module docs).
    restore: Mutex<()>,
    next_session: AtomicU64,
}

impl BalCore {
    fn lock_sessions(&self) -> MutexGuard<'_, BTreeMap<SessionId, BalSession>> {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Live-host placement order for a tenant: rendezvous over the full
    /// host list (so placement is stable as hosts die and revive),
    /// filtered to hosts currently believed alive.
    fn host_order(&self, snap: &SessionSnapshot) -> Vec<usize> {
        rendezvous_rank(tenant_key(&snap.cfg, snap.d, snap.seed), self.hosts.len())
            .into_iter()
            .filter(|&i| self.hosts[i].alive.load(Ordering::SeqCst))
            .collect()
    }

    /// Open-or-restore `snap` on the best live host (`SessionRestore`
    /// at `rounds = 0` is exactly an open). Returns the host index and
    /// the *backend* session id granted there.
    fn place(&self, snap: &SessionSnapshot) -> Result<(usize, SessionId), Error> {
        let mut last: Option<Error> = None;
        for i in self.host_order(snap) {
            // `codec: None` here: the backend connection injects its own
            // negotiation ask (see `ServiceClient::call`), and the
            // client-side ask was already consumed at the balancer tier.
            let restore = Request::SessionRestore { snapshot: snap.clone(), codec: None };
            match self.hosts[i].call(&restore) {
                Ok(Response::Admission(AdmissionReply {
                    session: Some(sid), error: None, ..
                })) => {
                    return Ok((i, sid));
                }
                Ok(Response::Admission(AdmissionReply { error: Some(e), .. })) => {
                    last = Some(Error::Admission(e));
                }
                Ok(other) => last = Some(Error::Unexpected(format!("{other:?}"))),
                Err(e) => last = Some(e), // host marked dead; try the next
            }
        }
        Err(last.unwrap_or(Error::NoLiveHosts))
    }

    /// Forward a session-scoped request, failing over transparently: a
    /// transport error restores the session on the next live host (from
    /// its tracked snapshot) and retries the request there.
    fn forward(
        &self,
        client_sid: SessionId,
        make: impl Fn(SessionId) -> Request,
    ) -> Result<Response, Error> {
        for _ in 0..(self.hosts.len() + 1) {
            let (host, backend) = match self.lock_sessions().get(&client_sid) {
                Some(bs) => (bs.host, bs.backend_sid),
                None => return Err(Error::UnknownSession(client_sid)),
            };
            match self.hosts[host].call(&make(backend)) {
                Err(Error::Io(_)) => self.failover(client_sid, host, backend)?,
                // The host answers but lost the session: it restarted
                // between health pings (the "unknown session" phrasing
                // is pinned by `error.rs`). The entry is stranded —
                // restore it exactly like a transport fail-over. A
                // session the *client* never opened can't reach here:
                // the table lookup above already screened it.
                Ok(Response::Admission(AdmissionReply {
                    error: Some(AdmissionError::Rejected { ref reason }),
                    ..
                })) if reason.starts_with("unknown session") => {
                    self.failover(client_sid, host, backend)?;
                }
                other => return other,
            }
        }
        Err(Error::Unexpected(format!(
            "session {client_sid} kept failing over across {} hosts",
            self.hosts.len()
        )))
    }

    /// Move `client_sid` off dead `host` (if no concurrent request beat
    /// us to it — the restore lock plus a placement re-check make the
    /// restore exactly-once).
    fn failover(&self, client_sid: SessionId, host: usize, backend: SessionId) -> Result<(), Error> {
        let _serial = self.restore.lock().unwrap_or_else(|p| p.into_inner());
        let snap = match self.lock_sessions().get(&client_sid) {
            None => return Err(Error::UnknownSession(client_sid)),
            // Already restored by whoever held the lock before us.
            Some(bs) if bs.host != host || bs.backend_sid != backend => return Ok(()),
            Some(bs) => bs.snap.clone(),
        };
        let (new_host, new_sid) = self.place(&snap)?;
        if let Some(bs) = self.lock_sessions().get_mut(&client_sid) {
            bs.host = new_host;
            bs.backend_sid = new_sid;
        }
        Ok(())
    }

    /// Answer one client request (the balancer's analogue of
    /// `AggFrontend::handle`). Returns the reply plus whether it was a
    /// shutdown.
    fn handle(&self, req: &Request) -> (Response, bool) {
        let reply = match req {
            // The client's codec ask (if any) is answered by the pump's
            // negotiation ack at *this* tier; what the backends speak is
            // the backend connections' own negotiation.
            Request::SessionOpen { cfg, d, seed, qos, codec: _ } => self.open(SessionSnapshot {
                cfg: *cfg,
                d: *d,
                seed: *seed,
                qos: *qos,
                rounds: 0,
            }),
            Request::SessionRestore { snapshot, codec: _ } => self.open(snapshot.clone()),
            Request::RoundSubmit { session, signs, present } => {
                let signs = signs.clone();
                let present = present.clone();
                match self.forward(*session, move |sid| Request::RoundSubmit {
                    session: sid,
                    signs: signs.clone(),
                    present: present.clone(),
                }) {
                    Ok(Response::Vote(mut v)) => {
                        // The vote is now client-observed: advance the
                        // restore point past this round and re-label the
                        // reply with the client's id. Churn rounds count
                        // like any other — the backend consumed exactly
                        // one round of its dealer stream either way.
                        if let Some(bs) = self.lock_sessions().get_mut(session) {
                            bs.snap.rounds += 1;
                        }
                        v.session = *session;
                        Response::Vote(v)
                    }
                    Ok(Response::Admission(mut a)) => {
                        // Typed denials (throttles, churn aborts) carry
                        // the backend's id — re-label with the client's.
                        a.session = a.session.map(|_| *session);
                        Response::Admission(a)
                    }
                    Ok(other) => other,
                    Err(e) => error_reply(Some(*session), e),
                }
            }
            Request::Prefetch { session, rounds } => {
                let rounds = *rounds;
                match self.forward(*session, move |sid| Request::Prefetch {
                    session: sid,
                    rounds,
                }) {
                    Ok(Response::Admission(mut a)) => {
                        a.session = a.session.map(|_| *session);
                        Response::Admission(a)
                    }
                    Ok(other) => other,
                    Err(e) => error_reply(Some(*session), e),
                }
            }
            Request::SessionClose { session } => self.close(*session),
            Request::StatsQuery { session: Some(sid) } => {
                match self.forward(*sid, move |backend| Request::StatsQuery {
                    session: Some(backend),
                }) {
                    Ok(Response::Stats(mut s)) => {
                        s.session = Some(*sid);
                        Response::Stats(s)
                    }
                    Ok(other) => other,
                    Err(e) => error_reply(Some(*sid), e),
                }
            }
            Request::StatsQuery { session: None } => self.cluster_stats(),
            // Answered locally: the balancer's rounds counter is the
            // authoritative restore point (and still works while the
            // session's host is down).
            Request::SessionSnapshot { session } => match self.lock_sessions().get(session) {
                Some(bs) => Response::Snapshot(SnapshotReply {
                    session: *session,
                    snapshot: bs.snap.clone(),
                }),
                None => error_reply(Some(*session), Error::UnknownSession(*session)),
            },
            // Answered locally from the table: this is how clients
            // re-discover their sessions (by `(cfg, d, seed)` match)
            // after a balancer restart rebuilt the table under fresh
            // client ids.
            Request::SessionList => {
                let sessions = self.lock_sessions();
                Response::Sessions(SessionListReply {
                    sessions: sessions
                        .iter()
                        .map(|(sid, bs)| SnapshotReply { session: *sid, snapshot: bs.snap.clone() })
                        .collect(),
                })
            }
            Request::SessionDiscard { session } => self.discard(*session),
            Request::Shutdown => {
                // Wind down the whole cluster: every live backend gets
                // the shutdown, best-effort, then the balancer stops.
                for host in &self.hosts {
                    if host.alive.load(Ordering::SeqCst) {
                        let _ = host.call(&Request::Shutdown);
                    }
                }
                return (Response::Admission(AdmissionReply::ok(None)), true);
            }
        };
        (reply, false)
    }

    fn open(&self, snap: SessionSnapshot) -> Response {
        // Serialized with fail-over and reconciliation: a placement
        // that raced a host sweep could be adopted twice (once by the
        // open, once re-placed by the sweep that didn't see it yet).
        let _serial = self.restore.lock().unwrap_or_else(|p| p.into_inner());
        match self.place(&snap) {
            Ok((host, backend_sid)) => {
                let sid = SessionId::new(self.next_session.fetch_add(1, Ordering::Relaxed));
                self.lock_sessions().insert(sid, BalSession { host, backend_sid, snap });
                Response::Admission(AdmissionReply::ok(Some(sid)))
            }
            Err(e) => error_reply(None, e),
        }
    }

    fn close(&self, client_sid: SessionId) -> Response {
        // Serialized with reconciliation so a sweep never re-places a
        // session that is mid-close.
        let _serial = self.restore.lock().unwrap_or_else(|p| p.into_inner());
        let bs = match self.lock_sessions().remove(&client_sid) {
            Some(bs) => bs,
            None => return error_reply(Some(client_sid), Error::UnknownSession(client_sid)),
        };
        // Best-effort: a dead host's sessions are already gone.
        let _ = self.hosts[bs.host].call(&Request::SessionClose { session: bs.backend_sid });
        Response::Admission(AdmissionReply::ok(Some(client_sid)))
    }

    /// The discard analogue of [`close`](BalCore::close): drop the
    /// session everywhere *without* folding its counters anywhere.
    fn discard(&self, client_sid: SessionId) -> Response {
        let _serial = self.restore.lock().unwrap_or_else(|p| p.into_inner());
        let bs = match self.lock_sessions().remove(&client_sid) {
            Some(bs) => bs,
            None => return error_reply(Some(client_sid), Error::UnknownSession(client_sid)),
        };
        let _ = self.hosts[bs.host].call(&Request::SessionDiscard { session: bs.backend_sid });
        Response::Admission(AdmissionReply::ok(Some(client_sid)))
    }

    /// Reconcile a host that just came back from the dead (see the
    /// module docs). Serialized with fail-over restores by the same
    /// lock, so an entry is never re-placed twice concurrently.
    fn reconcile_host(&self, host: usize) {
        let _serial = self.restore.lock().unwrap_or_else(|p| p.into_inner());
        // What the revived host actually holds. A failed sweep means
        // the host died again mid-revive: the next dead→alive
        // transition will retry.
        let listed: BTreeSet<SessionId> = match self.hosts[host].call(&Request::SessionList) {
            Ok(Response::Sessions(r)) => r.sessions.iter().map(|e| e.session).collect(),
            _ => return,
        };
        // What the table still claims there (collected without holding
        // the sessions lock across backend calls).
        let claimed: Vec<(SessionId, SessionId, SessionSnapshot)> = self
            .lock_sessions()
            .iter()
            .filter(|(_, bs)| bs.host == host)
            .map(|(sid, bs)| (*sid, bs.backend_sid, bs.snap.clone()))
            .collect();
        // (a) Stranded entries: the host restarted and lost them.
        // Re-place from the balancer's snapshot — the revived host is
        // back in the placement order, so the session may well land
        // right back where rendezvous wants it.
        for (client_sid, backend_sid, snap) in &claimed {
            if listed.contains(backend_sid) {
                continue;
            }
            if let Ok((new_host, new_sid)) = self.place(snap) {
                if let Some(bs) = self.lock_sessions().get_mut(client_sid) {
                    bs.host = new_host;
                    bs.backend_sid = new_sid;
                }
            }
        }
        // (b) Stale backend sessions nobody claims: their tenants were
        // restored elsewhere while the host was down. Discard — never
        // close — so the twin's continuous counters stay the only copy.
        let claimed_backends: BTreeSet<SessionId> =
            claimed.iter().map(|(_, backend, _)| *backend).collect();
        for stale in listed.difference(&claimed_backends) {
            let _ = self.hosts[host].call(&Request::SessionDiscard { session: *stale });
        }
    }

    /// Repopulate an empty session table from host-side state: sweep
    /// every reachable host with `SessionList` and adopt each listed
    /// session under a fresh client id. This is what lets a restarted
    /// balancer pick up a live cluster instead of starting blind.
    fn rebuild_sessions(&self) {
        for (host, handle) in self.hosts.iter().enumerate() {
            let listed = match handle.call(&Request::SessionList) {
                Ok(Response::Sessions(r)) => r.sessions,
                _ => continue, // dead host: its sessions fail over on first touch
            };
            let mut sessions = self.lock_sessions();
            for e in listed {
                let already = sessions
                    .values()
                    .any(|bs| bs.backend_sid == e.session && bs.host == host);
                if already {
                    continue;
                }
                let sid = SessionId::new(self.next_session.fetch_add(1, Ordering::Relaxed));
                sessions
                    .insert(sid, BalSession { host, backend_sid: e.session, snap: e.snapshot });
            }
        }
    }

    /// Cluster-wide stats: the fold of every live host's frontend-wide
    /// reply, with `shard_tenants` concatenated in host order (dead
    /// hosts contribute nothing — their counters are on the floor with
    /// them, which the reply's lower-bound semantics already allow).
    fn cluster_stats(&self) -> Response {
        let mut admission = AdmissionStats::default();
        let mut rounds_run = 0u64;
        let mut dealt_rounds = 0u64;
        let mut shard_tenants: Vec<usize> = Vec::new();
        for host in &self.hosts {
            if !host.alive.load(Ordering::SeqCst) {
                continue;
            }
            if let Ok(Response::Stats(s)) = host.call(&Request::StatsQuery { session: None }) {
                admission.merge(&s.admission);
                rounds_run += s.rounds_run;
                dealt_rounds += s.dealt_rounds;
                shard_tenants.extend(s.shard_tenants.unwrap_or_default());
            }
        }
        Response::Stats(StatsReply {
            session: None,
            shard: None,
            rounds_run,
            dealt_rounds,
            admission,
            shard_tenants: Some(shard_tenants),
        })
    }
}

fn error_reply(session: Option<SessionId>, e: Error) -> Response {
    Response::Admission(AdmissionReply::denied(session, e.into_admission()))
}

/// The routing core as a pump handler: route, answer. Exactly the
/// denial discipline the backend transport applies, so a garbage client
/// costs a typed reply at the balancer tier too (the shared pump
/// already decoded — or failed to decode — the frame, in either codec).
impl FrameHandler for BalCore {
    fn handle_frame(&self, req: &Result<Request, ProtoError>) -> (Response, bool) {
        match req {
            Ok(req) => self.handle(req),
            Err(e) => (
                Response::Admission(AdmissionReply::denied(
                    None,
                    AdmissionError::Rejected { reason: e.msg.clone() },
                )),
                false,
            ),
        }
    }
}

/// The balancer process: a listener for clients, the shared routing
/// core, the health-check cadence, and the connection-worker pool size.
pub struct Balancer {
    listener: TcpListener,
    core: Arc<BalCore>,
    stop: Arc<AtomicBool>,
    health_every: Duration,
    workers: usize,
    codec: Codec,
}

impl Balancer {
    /// Bind the client-facing listener at `addr`, fronting `hosts`
    /// (each a `hisafe serve` address), with the default worker pool.
    /// Hosts start presumed alive; the first failed call or health ping
    /// corrects that.
    pub fn bind(addr: &str, hosts: &[String], health_every: Duration) -> io::Result<Balancer> {
        Self::bind_with_workers(addr, hosts, health_every, DEFAULT_WORKERS)
    }

    /// Like [`bind`](Balancer::bind) with an explicit connection-worker
    /// count — the same knob [`super::server::ServiceServer`] exposes,
    /// because both listeners now run the same bounded pump.
    pub fn bind_with_workers(
        addr: &str,
        hosts: &[String],
        health_every: Duration,
        workers: usize,
    ) -> io::Result<Balancer> {
        assert!(!hosts.is_empty(), "a balancer needs at least one backend host");
        assert!(workers >= 1, "the balancer needs at least one connection worker");
        Ok(Balancer {
            listener: TcpListener::bind(addr)?,
            core: Arc::new(BalCore {
                hosts: hosts
                    .iter()
                    .cloned()
                    .map(|a| HostHandle::new(a, Codec::Binary))
                    .collect(),
                sessions: Mutex::new(BTreeMap::new()),
                restore: Mutex::new(()),
                next_session: AtomicU64::new(0),
            }),
            stop: Arc::new(AtomicBool::new(false)),
            health_every,
            workers,
            codec: Codec::Binary,
        })
    }

    /// Restrict the balancer to `codec` on *both* of its sides: what it
    /// acks to its own clients (the same knob as
    /// [`ServiceServer::with_codec`](super::server::ServiceServer::with_codec))
    /// and what its backend connections ask the `serve` hosts for. The
    /// default is binary-capable on both; `Codec::Json` forces the whole
    /// tier onto debuggable JSON frames. Must be called before
    /// [`serve`](Balancer::serve).
    pub fn with_codec(mut self, codec: Codec) -> Balancer {
        self.codec = codec;
        let core = Arc::get_mut(&mut self.core)
            .expect("with_codec must be called before serve() shares the core");
        for host in &mut core.hosts {
            host.want = codec;
        }
        self
    }

    /// The bound client-facing address.
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A handle that can stop *this balancer process* from another
    /// thread without winding down the backends (unlike the protocol's
    /// `Shutdown`, which fans out to the whole cluster). This is what a
    /// balancer-restart drill uses: stop the old balancer, keep the
    /// hosts, bind a fresh one and let
    /// [`rebuild`](BalCore::rebuild_sessions) repopulate its table.
    pub fn stop_handle(&self) -> io::Result<BalancerHandle> {
        Ok(BalancerHandle { stop: Arc::clone(&self.stop), addr: self.local_addr()? })
    }

    /// Accept-and-route until a client sends `Shutdown` (which also
    /// winds down every live backend) or a [`BalancerHandle`] stops
    /// this process. Before accepting, the session table is rebuilt
    /// from host-side state (a no-op sweep on a fresh cluster). Client
    /// connections are served by the shared bounded connection-worker
    /// pump ([`super::server::serve_frames`]); the health thread runs
    /// for the duration and is joined before this returns.
    pub fn serve(self) -> io::Result<()> {
        self.core.rebuild_sessions();
        let health = {
            let core = Arc::clone(&self.core);
            let stop = Arc::clone(&self.stop);
            let every = self.health_every;
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    for (i, host) in core.hosts.iter().enumerate() {
                        // A successful ping revives a dead host (call()
                        // flips `alive` on success, reconnecting first);
                        // a dead→alive transition reconciles the host's
                        // sessions against the table (see module docs).
                        let before = host.alive.load(Ordering::SeqCst);
                        let _ = host.call(&Request::StatsQuery { session: None });
                        if !before && host.alive.load(Ordering::SeqCst) {
                            core.reconcile_host(i);
                        }
                    }
                    std::thread::sleep(every);
                }
            })
        };
        let result = serve_frames(
            self.listener,
            self.core,
            Arc::clone(&self.stop),
            self.workers,
            self.codec,
        );
        self.stop.store(true, Ordering::SeqCst);
        let _ = health.join();
        result
    }
}

/// Stops one balancer process (flag + self-connect to wake the accept
/// loop) without touching the backends. Obtained from
/// [`Balancer::stop_handle`] before `serve` consumes the balancer.
pub struct BalancerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl BalancerHandle {
    /// Stop the balancer's accept loop and workers. Idempotent;
    /// `serve()` returns `Ok` after the in-flight sweep completes.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept; an error just means the listener
        // already closed.
        let _ = TcpStream::connect(self.addr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::QosPolicy;
    use crate::poly::TiePolicy;
    use crate::protocol::{
        plain_hierarchical_vote, plain_hierarchical_vote_present, HiSafeConfig, ParticipantSet,
    };
    use crate::service::{AggFrontend, ServiceServer};
    use crate::util::rng::{Rng, Xoshiro256pp};
    use std::time::Instant;

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    fn spawn_backend() -> (String, std::thread::JoinHandle<io::Result<()>>) {
        let server = ServiceServer::bind("127.0.0.1:0", AggFrontend::new(2, 1)).expect("bind");
        let addr = server.local_addr().expect("addr").to_string();
        (addr, std::thread::spawn(move || server.serve()))
    }

    fn spawn_balancer(
        hosts: &[String],
    ) -> (String, std::thread::JoinHandle<io::Result<()>>) {
        let (addr, _stopper, handle) = spawn_balancer_with_stopper(hosts);
        (addr, handle)
    }

    fn spawn_balancer_with_stopper(
        hosts: &[String],
    ) -> (String, BalancerHandle, std::thread::JoinHandle<io::Result<()>>) {
        let bal =
            Balancer::bind("127.0.0.1:0", hosts, Duration::from_millis(20)).expect("bind bal");
        let addr = bal.local_addr().expect("addr").to_string();
        let stopper = bal.stop_handle().expect("stop handle");
        (addr, stopper, std::thread::spawn(move || bal.serve()))
    }

    #[test]
    fn balanced_cluster_fails_over_with_bit_identical_votes() {
        let (a0, h0) = spawn_backend();
        let (a1, h1) = spawn_backend();
        let hosts = vec![a0.clone(), a1.clone()];
        let (bal_addr, bal) = spawn_balancer(&hosts);

        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let (d, seed) = (5usize, 7u64);
        let mut client = ServiceClient::connect(&bal_addr).expect("connect balancer");
        let sid = client.open_session(cfg, d, seed, QosPolicy::unlimited()).expect("admitted");

        // The balancer places by the same rendezvous the frontends use,
        // so the test knows which host the session landed on — and
        // kills exactly that one mid-sweep.
        let victim = rendezvous_rank(tenant_key(&cfg, d, seed), 2)[0];
        let (victim_addr, victim_handle, survivor_handle) =
            if victim == 0 { (a0, h0, h1) } else { (a1, h1, h0) };

        let rounds = 5u64;
        for r in 0..rounds {
            let signs = rand_signs(6, d, 400 + r);
            if r == 2 {
                // Kill the victim host out from under its session.
                let mut killer = ServiceClient::connect(&victim_addr).expect("connect victim");
                killer.shutdown().expect("victim shutdown acked");
                victim_handle.join().expect("victim thread").expect("victim clean exit");
            }
            let vote = client.submit_round(sid, &signs).expect("round survives fail-over");
            assert_eq!(
                vote.global_vote,
                plain_hierarchical_vote(&signs, cfg),
                "round {r} must be bit-identical across the host kill"
            );
            assert_eq!(vote.session, sid, "replies carry the client's id");
        }

        // Post-failover bookkeeping: the snapshot shows every round,
        // and session stats (served by the surviving host) agree.
        let snap = client.snapshot_session(sid).expect("snapshot");
        assert_eq!(snap.rounds, rounds);
        let stats = client.stats(Some(sid)).expect("session stats");
        assert_eq!(stats.session, Some(sid));
        assert_eq!(stats.rounds_run, rounds, "restored counters are continuous");
        client.close_session(sid).expect("close acked");

        // Shutdown fans out: the surviving backend exits too.
        client.shutdown().expect("cluster shutdown acked");
        bal.join().expect("balancer thread").expect("balancer clean exit");
        survivor_handle.join().expect("survivor thread").expect("survivor clean exit");
    }

    #[test]
    fn churn_masks_forward_through_the_balancer_with_typed_aborts() {
        let (a0, h0) = spawn_backend();
        let (bal_addr, bal) = spawn_balancer(&[a0]);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut client = ServiceClient::connect(&bal_addr).expect("connect");
        let sid = client.open_session(cfg, 5, 3, QosPolicy::unlimited()).expect("admitted");
        let signs = rand_signs(6, 5, 31);
        // The mask forwards through the proxy tier untouched.
        let mask = vec![false, true, true, true, true, true];
        let vote = client.submit_round_present(sid, &signs, &mask).expect("churn admitted");
        let set = ParticipantSet::from_mask(mask);
        assert_eq!(vote.global_vote, plain_hierarchical_vote_present(&signs, &set, cfg));
        assert_eq!(vote.session, sid, "replies carry the client's id");
        // A below-threshold abort crosses both tiers typed, re-labeled
        // with the client's session id, and does not advance the restore
        // point (no vote was observed).
        match client.submit_round_present(sid, &signs, &[false, false, true, true, true, true]) {
            Err(Error::Admission(AdmissionError::ChurnBelowThreshold {
                group: 0,
                survivors: 1,
                required: 2,
            })) => {}
            other => panic!("expected a typed churn abort, got {other:?}"),
        }
        let snap = client.snapshot_session(sid).expect("snapshot");
        assert_eq!(snap.rounds, 1, "aborted churn rounds are not client-observed votes");
        client.shutdown().expect("shutdown acked");
        bal.join().expect("balancer thread").expect("balancer clean exit");
        h0.join().expect("h0 thread").expect("h0 clean exit");
    }

    #[test]
    fn codec_negotiation_is_independent_per_tier() {
        // A binary-asking client in front, JSON-only backends behind:
        // the balancer acks binary to its client while its backend
        // connections stay on JSON (the backends never ack) — and votes
        // are still bit-identical to the reference.
        let backend = ServiceServer::bind("127.0.0.1:0", AggFrontend::new(2, 1))
            .expect("bind")
            .with_codec(crate::service::Codec::Json);
        let a0 = backend.local_addr().expect("addr").to_string();
        let h0 = std::thread::spawn(move || backend.serve());
        let (bal_addr, bal) = spawn_balancer(&[a0]);

        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut client =
            ServiceClient::connect_with_codec(&bal_addr, crate::service::Codec::Binary)
                .expect("connect");
        let sid = client.open_session(cfg, 5, 8, QosPolicy::unlimited()).expect("admitted");
        assert_eq!(
            client.codec(),
            crate::service::Codec::Binary,
            "the balancer tier acks binary regardless of what its backends speak"
        );
        for r in 0..3u64 {
            let signs = rand_signs(6, 5, 500 + r);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        }
        client.shutdown().expect("shutdown acked");
        bal.join().expect("balancer thread").expect("balancer clean exit");
        h0.join().expect("h0 thread").expect("h0 clean exit");
    }

    #[test]
    fn revived_host_rejoins_and_stranded_sessions_reconcile() {
        // One host, so the kill strands the session with nowhere to
        // fail over: only the health thread's dead→alive reconciliation
        // can bring it back.
        let (a0, h0) = spawn_backend();
        let (bal_addr, bal) = spawn_balancer(&[a0.clone()]);

        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let (d, seed) = (5usize, 11u64);
        let mut client = ServiceClient::connect(&bal_addr).expect("connect balancer");
        let sid = client.open_session(cfg, d, seed, QosPolicy::unlimited()).expect("admitted");
        for r in 0..2u64 {
            let signs = rand_signs(6, d, 600 + r);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        }

        // Kill the only host out from under the session...
        let mut killer = ServiceClient::connect(&a0).expect("connect host");
        killer.shutdown().expect("host shutdown acked");
        h0.join().expect("host thread").expect("host clean exit");
        // ...and revive it at the same address with a fresh (empty)
        // frontend, exactly as a restarted `hisafe serve` would.
        let revived = ServiceServer::bind(&a0, AggFrontend::new(2, 1)).expect("rebind host addr");
        let h0 = std::thread::spawn(move || revived.serve());

        // Without touching the session, wait for the health ping to see
        // the dead→alive transition and reconcile: the stranded entry
        // is re-placed from the balancer's snapshot onto the revived
        // host, counters continuous. (Cluster stats skip dead hosts and
        // the revived host starts empty, so `rounds_run == 2` is
        // observable only once the re-placement happened.)
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let stats = client.stats(None).expect("cluster stats");
            if stats.rounds_run == 2 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "health thread never reconciled the revived host"
            );
            std::thread::sleep(Duration::from_millis(5));
        }

        // The session keeps going bit-identically under its old id.
        let signs = rand_signs(6, d, 602);
        let vote = client.submit_round(sid, &signs).expect("round survives the re-join");
        assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(vote.session, sid, "replies carry the client's id");
        let snap = client.snapshot_session(sid).expect("snapshot");
        assert_eq!(snap.rounds, 3);
        let stats = client.stats(Some(sid)).expect("session stats");
        assert_eq!(stats.rounds_run, 3, "restored counters are continuous");

        client.close_session(sid).expect("close acked");
        client.shutdown().expect("cluster shutdown acked");
        bal.join().expect("balancer thread").expect("balancer clean exit");
        h0.join().expect("revived host thread").expect("revived host clean exit");
    }

    #[test]
    fn restarted_balancer_rebuilds_its_session_table() {
        let (a0, h0) = spawn_backend();
        let (a1, h1) = spawn_backend();
        let hosts = vec![a0, a1];
        let (bal_addr, stopper, bal) = spawn_balancer_with_stopper(&hosts);

        let cfg = HiSafeConfig::hierarchical(4, 2, TiePolicy::OneBit);
        let d = 4usize;
        let mut client = ServiceClient::connect(&bal_addr).expect("connect balancer");
        let seeds = [21u64, 22, 23];
        let sids: Vec<SessionId> = seeds
            .iter()
            .map(|&s| client.open_session(cfg, d, s, QosPolicy::unlimited()).expect("admitted"))
            .collect();
        for (i, &sid) in sids.iter().enumerate() {
            for r in 0..2u64 {
                let signs = rand_signs(4, d, 700 + 10 * i as u64 + r);
                let vote = client.submit_round(sid, &signs).expect("round admitted");
                assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
            }
        }

        // Stop the balancer *process*; the backends — and their
        // sessions — stay up.
        stopper.stop();
        bal.join().expect("balancer thread").expect("balancer clean exit");
        drop(client);

        // A fresh balancer on a fresh port rebuilds its table from the
        // hosts before accepting clients.
        let (bal_addr, bal) = spawn_balancer(&hosts);
        let mut client = ServiceClient::connect(&bal_addr).expect("connect new balancer");
        let listed = match client.call(&Request::SessionList).expect("session list") {
            Response::Sessions(r) => r.sessions,
            other => panic!("expected a session list, got {other:?}"),
        };
        assert_eq!(listed.len(), seeds.len(), "the rebuilt table holds every live session");
        // Clients re-discover their sessions by (cfg, d, seed): the ids
        // are fresh, the snapshots are the hosts' authoritative state.
        let rediscovered: Vec<SessionId> = seeds
            .iter()
            .map(|&s| {
                let e = listed
                    .iter()
                    .find(|e| e.snapshot.cfg == cfg && e.snapshot.d == d && e.snapshot.seed == s)
                    .expect("session rediscovered by tenant identity");
                assert_eq!(e.snapshot.rounds, 2, "rebuilt snapshots carry the round counts");
                e.session
            })
            .collect();
        for (i, &sid) in rediscovered.iter().enumerate() {
            let signs = rand_signs(4, d, 730 + i as u64);
            let vote = client.submit_round(sid, &signs).expect("round survives the restart");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
            assert_eq!(vote.session, sid, "replies carry the fresh client id");
            let stats = client.stats(Some(sid)).expect("session stats");
            assert_eq!(stats.rounds_run, 3, "backend counters were never interrupted");
        }
        for &sid in &rediscovered {
            client.close_session(sid).expect("close acked");
        }
        client.shutdown().expect("cluster shutdown acked");
        bal.join().expect("balancer thread").expect("balancer clean exit");
        h0.join().expect("h0 thread").expect("h0 clean exit");
        h1.join().expect("h1 thread").expect("h1 clean exit");
    }

    #[test]
    fn displaced_then_restored_session_counts_exactly_once_in_cluster_stats() {
        // Drive the routing core directly so the test can stage the
        // nasty interleaving: a host partitioned from the balancer
        // (marked dead) while its backend session stays alive — the
        // stale-copy scenario reconciliation's discard-not-close rule
        // exists for.
        let (a0, h0) = spawn_backend();
        let (a1, h1) = spawn_backend();
        let core = BalCore {
            hosts: vec![HostHandle::new(a0, Codec::Binary), HostHandle::new(a1, Codec::Binary)],
            sessions: Mutex::new(BTreeMap::new()),
            restore: Mutex::new(()),
            next_session: AtomicU64::new(0),
        };

        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let (d, seed) = (5usize, 13u64);
        let sid = match core.handle(&Request::SessionOpen {
            cfg,
            d,
            seed,
            qos: QosPolicy::unlimited(),
            codec: None,
        }) {
            (Response::Admission(AdmissionReply { session: Some(sid), error: None, .. }), false) => {
                sid
            }
            other => panic!("expected an admission, got {other:?}"),
        };
        let victim = rendezvous_rank(tenant_key(&cfg, d, seed), 2)[0];
        let survivor = 1 - victim;

        let submit = |core: &BalCore, r: u64| {
            let signs = rand_signs(6, d, 800 + r);
            match core.handle(&Request::RoundSubmit {
                session: sid,
                signs: signs.clone(),
                present: None,
            }) {
                (Response::Vote(v), false) => {
                    assert_eq!(v.global_vote, plain_hierarchical_vote(&signs, cfg));
                }
                other => panic!("round {r}: expected a vote, got {other:?}"),
            }
        };
        submit(&core, 0);
        submit(&core, 1);

        // Partition the victim: the balancer believes it dead and fails
        // the session over, but the victim process — and its now-stale
        // backend session, counters at 2 — keeps running.
        core.hosts[victim].alive.store(false, Ordering::SeqCst);
        let (old_host, old_backend) = {
            let sessions = core.lock_sessions();
            let bs = sessions.get(&sid).expect("tracked");
            (bs.host, bs.backend_sid)
        };
        assert_eq!(old_host, victim, "rendezvous placed the session on the victim");
        core.failover(sid, victim, old_backend).expect("failed over to the survivor");
        assert_eq!(core.lock_sessions().get(&sid).expect("tracked").host, survivor);
        submit(&core, 2);
        submit(&core, 3);

        // While partitioned, merged stats count the displaced session
        // exactly once: the survivor's restored (continuous) counters,
        // the dead victim contributing nothing.
        match core.cluster_stats() {
            Response::Stats(s) => assert_eq!(s.rounds_run, 4),
            other => panic!("expected stats, got {other:?}"),
        }

        // Heal the partition and reconcile: the stale copy on the
        // victim is *discarded*, not closed — closing would fold its 2
        // rounds into the victim's aggregate and double-count them next
        // to the restored twin's continuous 4.
        core.hosts[victim].alive.store(true, Ordering::SeqCst);
        core.reconcile_host(victim);
        match core.hosts[victim].call(&Request::StatsQuery { session: None }) {
            Ok(Response::Stats(s)) => {
                assert_eq!(s.rounds_run, 0, "the discarded stale copy folded nothing");
            }
            other => panic!("expected victim stats, got {other:?}"),
        }
        match core.cluster_stats() {
            Response::Stats(s) => {
                assert_eq!(s.rounds_run, 4, "exactly once across displacement and restore");
            }
            other => panic!("expected stats, got {other:?}"),
        }

        // Close folds the survivor's counters; the total is still 4.
        match core.handle(&Request::SessionClose { session: sid }) {
            (Response::Admission(AdmissionReply { error: None, .. }), false) => {}
            other => panic!("expected a close ack, got {other:?}"),
        }
        match core.cluster_stats() {
            Response::Stats(s) => assert_eq!(s.rounds_run, 4, "close folds, never double-counts"),
            other => panic!("expected stats, got {other:?}"),
        }

        match core.handle(&Request::Shutdown) {
            (Response::Admission(_), true) => {}
            other => panic!("expected a shutdown ack, got {other:?}"),
        }
        h0.join().expect("h0 thread").expect("h0 clean exit");
        h1.join().expect("h1 thread").expect("h1 clean exit");
    }

    #[test]
    fn cluster_stats_merge_across_hosts() {
        let (a0, h0) = spawn_backend();
        let (a1, h1) = spawn_backend();
        let (bal_addr, bal) = spawn_balancer(&[a0, a1]);

        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut client = ServiceClient::connect(&bal_addr).expect("connect");
        // Enough tenants that rendezvous virtually certainly uses both
        // hosts (and the assertion below doesn't depend on it anyway).
        let sids: Vec<SessionId> = (0..6)
            .map(|i| client.open_session(cfg, 4, i, QosPolicy::unlimited()).expect("admitted"))
            .collect();
        for (i, &sid) in sids.iter().enumerate() {
            let signs = rand_signs(3, 4, 40 + i as u64);
            client.submit_round(sid, &signs).expect("round admitted");
        }
        let stats = client.stats(None).expect("cluster stats");
        assert_eq!(stats.rounds_run, 6);
        assert_eq!(stats.admission.admitted_rounds, 6);
        // Two hosts x two shards each, concatenated in host order.
        let tenants = stats.shard_tenants.expect("cluster lists shards");
        assert_eq!(tenants.len(), 4);
        assert_eq!(tenants.iter().sum::<usize>(), 6);

        client.shutdown().expect("shutdown acked");
        bal.join().expect("balancer thread").expect("balancer clean exit");
        h0.join().expect("h0 thread").expect("h0 clean exit");
        h1.join().expect("h1 thread").expect("h1 clean exit");
    }
}
