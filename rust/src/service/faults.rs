//! Deterministic, seeded chaos harness for the whole service stack.
//!
//! A [`FaultPlan`] is a **pure function of a u64 seed**: it scripts a
//! small cluster topology (two `serve` hosts of two shards each behind
//! one balancer), a handful of tenants, and a per-round schedule of
//! injectable [`Fault`]s — kill a host mid-sweep, revive it later,
//! truncate or corrupt a frame mid-write, duplicate a read, poison a
//! shard, restart the balancer. [`run_schedule`] then executes the plan
//! against a *real* in-process cluster (real TCP on loopback, the real
//! pump, the real balancer) and asserts the anchor invariant after
//! every fault:
//!
//! * every client-observed vote is **bit-identical** to the plaintext
//!   reference ([`plain_quant_aggregate`] /
//!   [`plain_quant_aggregate_present`], which the secure paths are
//!   pinned to elsewhere — the legacy sign reference at precision 2)
//!   over the plan's survivor sets and at each tenant's quantization
//!   precision (plans draw per-tenant precisions from the seed stream,
//!   and at least one q > 2 tenant is guaranteed per plan);
//! * below-threshold churn rounds abort with the same **typed**
//!   [`AdmissionError::ChurnBelowThreshold`] the local engine raises;
//! * no schedule wedges the connection-worker pump (the run ends with a
//!   clean cluster-wide shutdown whose serve loops all join `Ok`);
//! * no schedule leaks sessions (every host drains to
//!   `live_sessions() == 0` and the balancer's table empties).
//!
//! Everything is reproducible from the seed alone: the signs, the
//! masks, the fault rounds, and the tenant shapes are all drawn from
//! one [`Xoshiro256pp`] stream. `rust/tests/chaos_props.rs` sweeps
//! seeds (override with `HISAFE_CHAOS_SEED=<seed>` to replay one);
//! `hisafe sweep --chaos-seed <seed>` runs a single schedule from the
//! CLI and prints its [`ChaosReport`].
//!
//! [`plain_quant_aggregate`]: crate::protocol::plain_quant_aggregate
//! [`plain_quant_aggregate_present`]: crate::protocol::plain_quant_aggregate_present
//! [`AdmissionError::ChurnBelowThreshold`]: crate::engine::AdmissionError::ChurnBelowThreshold

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{AdmissionError, QosPolicy, SessionId};
use crate::poly::TiePolicy;
use crate::protocol::{
    group_threshold, plain_quant_aggregate, plain_quant_aggregate_present, HiSafeConfig,
    ParticipantSet,
};
use crate::util::rng::{Rng, Xoshiro256pp};

use super::balancer::Balancer;
use super::binary;
use super::frontend::AggFrontend;
use super::proto::{Request, Response};
use super::server::{ServiceClient, ServiceServer};
use super::Error;

/// Hosts in every chaos topology (each with [`SHARDS`] scheduler shards).
pub const HOSTS: usize = 2;
/// Scheduler shards per host.
pub const SHARDS: usize = 2;
/// Tenants (sessions) per schedule.
pub const TENANTS: usize = 2;

/// One injectable fault. Faults are applied *before* the submissions of
/// the round they are scheduled at, in schedule order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Stop a serve host's process (clean transport death: its listener
    /// closes and every connection to it breaks).
    KillHost {
        /// Index of the host to kill (< [`HOSTS`]).
        host: usize,
    },
    /// Restart the killed host on the **same address** with a fresh
    /// (empty) frontend — the re-join case the balancer must reconcile.
    ReviveHost {
        /// Index of the host to revive.
        host: usize,
    },
    /// Stop the balancer (only it — the backends keep running) and bind
    /// a fresh one over the same host list: its session table must
    /// rebuild from host-side snapshots, and clients re-discover their
    /// sessions via `SessionList`.
    RestartBalancer,
    /// Poison one scheduler shard on a live host (in-process
    /// `kill_shard`): the frontend's shard-death absorption must restore
    /// the shard's sessions transparently with bit-identical votes.
    PoisonShard {
        /// Host whose frontend loses a shard.
        host: usize,
        /// Shard index to poison (< [`SHARDS`]).
        shard: usize,
    },
    /// A frame whose binary header is broken (bad framing version): the
    /// pump must answer typed, then drop *that* connection only.
    CorruptHeader,
    /// A well-framed payload of garbage bytes: typed reject, and the
    /// connection survives to serve the next frame.
    CorruptPayload,
    /// A frame header promising more payload than is ever written, then
    /// a mid-frame disconnect: the pump must drop the connection without
    /// wedging a worker.
    TruncateFrame,
    /// Issue the same cluster-wide stats read twice back-to-back (the
    /// duplicated-delivery case): the read path must be idempotent.
    DuplicateStats,
    /// Sleep briefly mid-schedule, letting the health/reconcile cadence
    /// interleave differently with the round stream.
    DelayRound {
        /// Milliseconds to sleep.
        ms: u64,
    },
    /// Run this round as a churn round for one tenant: either a
    /// survivor set above every subgroup's threshold (vote checked
    /// against the present-set reference) or one starved below it
    /// (typed abort checked).
    ChurnRound {
        /// Tenant whose round runs under a dropout mask.
        tenant: usize,
        /// Starve subgroup 0 below its reconstruction threshold.
        below_threshold: bool,
    },
}

impl Fault {
    /// Stable kind label, for coverage accounting across a seed sweep.
    pub fn kind(&self) -> &'static str {
        match self {
            Fault::KillHost { .. } => "kill_host",
            Fault::ReviveHost { .. } => "revive_host",
            Fault::RestartBalancer => "restart_balancer",
            Fault::PoisonShard { .. } => "poison_shard",
            Fault::CorruptHeader => "corrupt_header",
            Fault::CorruptPayload => "corrupt_payload",
            Fault::TruncateFrame => "truncate_frame",
            Fault::DuplicateStats => "duplicate_stats",
            Fault::DelayRound { .. } => "delay_round",
            Fault::ChurnRound { .. } => "churn_round",
        }
    }
}

/// One tenant's session shape, drawn from the plan seed.
#[derive(Debug, Clone, Copy)]
pub struct TenantPlan {
    /// Protocol configuration (small n so schedules stay fast).
    pub cfg: HiSafeConfig,
    /// Gradient dimension.
    pub d: usize,
    /// Session seed. Distinct per tenant within a plan, so sessions are
    /// matchable by `(cfg, d, seed)` after a balancer rebuild.
    pub seed: u64,
}

/// A deterministic chaos schedule: pure function of the seed, no clock,
/// no ambient randomness — the same seed always builds the same plan,
/// which is what makes every `chaos_props` failure replayable.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// The seed the plan was derived from.
    pub seed: u64,
    /// Tenant session shapes ([`TENANTS`] of them).
    pub tenants: Vec<TenantPlan>,
    /// Rounds every tenant submits.
    pub rounds: u64,
    /// `(round, fault)` pairs, applied before that round's submissions
    /// in vector order.
    pub schedule: Vec<(u64, Fault)>,
}

impl FaultPlan {
    /// Derive the full schedule from `seed`. Invariants the derivation
    /// guarantees: exactly one kill and one revive of the same host,
    /// kill before (or at the same round as) revive, at least one round
    /// after the revive, never more than one host down, and at least
    /// one frame-level fault per plan.
    pub fn from_seed(seed: u64) -> FaultPlan {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xc0a5_f00d_5eed_cafe);
        let mut tenants: Vec<TenantPlan> = (0..TENANTS as u64)
            .map(|t| {
                let cfg = match rng.gen_below(4) {
                    0 => HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit),
                    1 => HiSafeConfig::hierarchical(4, 2, TiePolicy::OneBit),
                    2 => HiSafeConfig::flat(3, TiePolicy::OneBit),
                    _ => HiSafeConfig::flat(4, TiePolicy::OneBit),
                };
                // Per-tenant quantization precision, from the same seed
                // stream (kept small — q ≤ 8 — so chaos fields stay
                // cheap; q = 16 coverage lives in the property suites).
                let q = [2u8, 2, 4, 8][rng.gen_below(4) as usize];
                TenantPlan {
                    cfg: cfg.with_precision(q),
                    d: 3 + rng.gen_below(4) as usize,
                    // Distinct by construction: tenant index in the low
                    // bits, a plan-level draw above them.
                    seed: (rng.gen_below(1 << 20) << 8) | t,
                }
            })
            .collect();
        // Every plan exercises the quantized path at least once: if the
        // draws came up all-legacy, promote one tenant (deterministic —
        // still a pure function of the seed stream).
        if tenants.iter().all(|t| t.cfg.precision == 2) {
            let promote = rng.gen_below(TENANTS as u64) as usize;
            tenants[promote].cfg = tenants[promote].cfg.with_precision(4);
        }
        let rounds = 5 + rng.gen_below(4); // 5..=8
        let mut schedule: Vec<(u64, Fault)> = Vec::new();

        // The guaranteed kill/revive pair. `immediate` revives in the
        // same round slot as the kill: the balancer never serves a
        // round against the dead host, so its table entries are
        // *stranded* on the restarted host — exercising re-join
        // reconciliation rather than request-driven fail-over.
        let victim = rng.gen_below(HOSTS as u64) as usize;
        let kill_at = 1 + rng.gen_below(rounds - 3); // 1..=rounds-3
        let immediate = rng.gen_below(4) == 0;
        let revive_at = if immediate {
            kill_at
        } else {
            kill_at + 1 + rng.gen_below(rounds - 1 - kill_at) // ..=rounds-1
        };
        schedule.push((kill_at, Fault::KillHost { host: victim }));
        schedule.push((revive_at, Fault::ReviveHost { host: victim }));

        // One frame-level fault per plan, against the balancer's pump.
        let frame_fault = match rng.gen_below(3) {
            0 => Fault::CorruptHeader,
            1 => Fault::CorruptPayload,
            _ => Fault::TruncateFrame,
        };
        schedule.push((rng.gen_below(rounds), frame_fault));

        // Seed-dependent extras.
        if !immediate && rng.gen_below(2) == 0 {
            // Only after a *non-immediate* revive: by then every tenant
            // has failed over onto the survivor (each round touches all
            // of them), so host-side state covers the whole table and
            // the rebuild sweep loses nothing. An immediate kill+revive
            // leaves sessions whose only copy is the old balancer's
            // snapshot — restarting it then would forget them, which is
            // a documented limit, not a recovery bug.
            schedule.push((revive_at, Fault::RestartBalancer));
        }
        if rng.gen_below(2) == 0 {
            // Poison a shard on whichever host is guaranteed alive at
            // that round: the non-victim always is.
            schedule.push((
                rng.gen_below(rounds),
                Fault::PoisonShard {
                    host: (victim + 1) % HOSTS,
                    shard: rng.gen_below(SHARDS as u64) as usize,
                },
            ));
        }
        if rng.gen_below(2) == 0 {
            schedule.push((rng.gen_below(rounds), Fault::DuplicateStats));
        }
        if rng.gen_below(2) == 0 {
            schedule.push((rng.gen_below(rounds), Fault::DelayRound { ms: 1 + rng.gen_below(10) }));
        }
        if rng.gen_below(2) == 0 {
            schedule.push((
                rng.gen_below(rounds),
                Fault::ChurnRound {
                    tenant: rng.gen_below(TENANTS as u64) as usize,
                    below_threshold: rng.gen_below(2) == 0,
                },
            ));
        }
        // Stable-sort by round so per-round application preserves the
        // push order above (kill before revive before restart).
        schedule.sort_by_key(|(round, _)| *round);
        FaultPlan { seed, tenants, rounds, schedule }
    }

    /// The faults scheduled at `round`, in application order.
    fn at(&self, round: u64) -> impl Iterator<Item = &Fault> {
        self.schedule.iter().filter(move |(r, _)| *r == round).map(|(_, f)| f)
    }
}

/// What a completed schedule did — returned (rather than printed) so
/// the CLI and the test suite can both account coverage.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// The schedule's seed.
    pub seed: u64,
    /// Rounds in the plan.
    pub rounds: u64,
    /// Client-observed votes checked bit-identical to the reference.
    pub votes_checked: u64,
    /// Typed below-threshold churn aborts observed.
    pub typed_aborts: u64,
    /// Kind labels ([`Fault::kind`]) of every fault applied, in order.
    pub faults: Vec<&'static str>,
    /// Each tenant's quantization precision, in plan order — for
    /// coverage accounting across a seed sweep (every plan carries at
    /// least one q > 2 tenant by construction).
    pub precisions: Vec<u8>,
}

/// Deterministic per-round vote matrix for one tenant: uniform over the
/// `q` odd midrise levels (`{−1, +1}` at `q = 2` — inputs are always
/// *levels*, never the even tie-merge outputs, matching what a real
/// quantizer submits).
fn round_signs(
    plan_seed: u64,
    tenant: usize,
    round: u64,
    n: usize,
    d: usize,
    q: u8,
) -> Vec<Vec<i8>> {
    let mut rng = Xoshiro256pp::seed_from_u64(
        plan_seed ^ 0x5169_7e5a ^ ((tenant as u64) << 40) ^ (round << 8),
    );
    (0..n)
        .map(|_| {
            (0..d)
                .map(|_| (2 * rng.gen_below(q as u64) as i64 - (q as i64 - 1)) as i8)
                .collect()
        })
        .collect()
}

/// One running serve host the harness can kill and revive in place.
struct Host {
    addr: String,
    frontend: Arc<AggFrontend>,
    handle: Option<std::thread::JoinHandle<std::io::Result<()>>>,
    alive: bool,
}

fn spawn_host(addr: &str) -> Host {
    let server = ServiceServer::bind(addr, AggFrontend::new(SHARDS, 1))
        .unwrap_or_else(|e| panic!("chaos host bind {addr}: {e}"));
    let addr = server.local_addr().expect("host addr").to_string();
    let frontend = server.frontend();
    let handle = std::thread::spawn(move || server.serve());
    Host { addr, frontend, handle: Some(handle), alive: true }
}

/// The health-ping cadence: short, so dead→alive reconciliation runs
/// well inside a schedule's lifetime.
const HEALTH_EVERY: Duration = Duration::from_millis(10);

struct Bal {
    addr: String,
    stopper: super::BalancerHandle,
    handle: std::thread::JoinHandle<std::io::Result<()>>,
}

fn spawn_balancer(hosts: &[String]) -> Bal {
    let bal = Balancer::bind("127.0.0.1:0", hosts, HEALTH_EVERY).expect("chaos balancer bind");
    let addr = bal.local_addr().expect("balancer addr").to_string();
    let stopper = bal.stop_handle().expect("balancer stop handle");
    let handle = std::thread::spawn(move || bal.serve());
    Bal { addr, stopper, handle }
}

/// Read one length-framed binary reply off a raw socket.
fn read_binary_reply(stream: &mut TcpStream) -> Vec<u8> {
    let mut hdr = [0u8; binary::HEADER_LEN];
    stream.read_exact(&mut hdr).expect("binary reply header");
    let len = binary::parse_header(&hdr).expect("reply header parses");
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload).expect("binary reply payload");
    payload
}

fn injector_socket(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).expect("injector connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    s
}

/// Bad framing version: the pump must answer typed *then* drop this
/// connection (without a trustworthy length there is no next frame
/// boundary).
fn inject_corrupt_header(addr: &str) {
    let mut s = injector_socket(addr);
    s.write_all(&[binary::MAGIC, binary::VERSION + 7, 16, 0, 0, 0]).expect("write bad header");
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf); // server replies, then EOF
    assert!(!buf.is_empty(), "a corrupt header earns a typed reject before the drop");
}

/// Well-framed garbage payload: typed reject, and the same connection
/// then serves a real (JSON) request — per-frame codec detection means
/// the pump never lost the frame boundary.
fn inject_corrupt_payload(addr: &str) {
    let mut s = injector_socket(addr);
    s.write_all(&binary::frame(&[0xEE, 0xEE, 0xEE])).expect("write garbage payload");
    let payload = read_binary_reply(&mut s);
    match binary::decode_response(&payload).expect("reject decodes") {
        Response::Admission(reply) => {
            assert!(reply.error.is_some(), "garbage payload must be denied, not acked")
        }
        other => panic!("expected a typed reject, got {other:?}"),
    }
    let mut line = Request::StatsQuery { session: None }.to_json().to_string_compact();
    line.push('\n');
    s.write_all(line.as_bytes()).expect("write follow-up stats");
    let mut byte = [0u8; 1];
    let mut reply = Vec::new();
    loop {
        s.read_exact(&mut byte).expect("read follow-up reply");
        if byte[0] == b'\n' {
            break;
        }
        reply.push(byte[0]);
    }
    assert!(
        !reply.is_empty(),
        "the connection must survive a malformed payload and serve the next frame"
    );
}

/// Header promising bytes that never arrive, then a disconnect: the
/// pump drops the connection; the caller's next round proves no worker
/// wedged waiting for the missing payload.
fn inject_truncated_frame(addr: &str) {
    let mut s = injector_socket(addr);
    s.write_all(&[binary::MAGIC, binary::VERSION, 64, 0, 0, 0]).expect("write header");
    s.write_all(&[0u8; 8]).expect("write partial payload");
    // Drop mid-frame.
}

/// A dropout mask for `plan`'s tenant: survivors stay above every
/// subgroup threshold unless `below_threshold`, which starves subgroup
/// 0 to exactly one survivor short.
fn churn_mask(cfg: HiSafeConfig, below_threshold: bool) -> Vec<bool> {
    let n1 = cfg.n1();
    let required = group_threshold(n1) + 1;
    let mut mask = vec![true; cfg.n];
    if below_threshold {
        // Subgroup 0 is users 0..n1 (contiguous partition): keep only
        // `required - 1` of them.
        for bit in mask.iter_mut().take(n1 - (required - 1)) {
            *bit = false;
        }
    } else {
        // Drop one member of subgroup 0; every shape the plans draw
        // keeps `n1 - 1 >= required`.
        mask[0] = false;
    }
    mask
}

/// Execute the schedule for `seed` against a real loopback cluster and
/// assert every invariant. Panics (with the offending context) on any
/// violation — the caller prints the seed, which replays the identical
/// schedule.
pub fn run_schedule(seed: u64) -> ChaosReport {
    let plan = FaultPlan::from_seed(seed);
    let mut report = ChaosReport {
        seed,
        rounds: plan.rounds,
        votes_checked: 0,
        typed_aborts: 0,
        faults: Vec::new(),
        precisions: plan.tenants.iter().map(|t| t.cfg.precision).collect(),
    };

    let mut hosts: Vec<Host> = (0..HOSTS).map(|_| spawn_host("127.0.0.1:0")).collect();
    let host_addrs: Vec<String> = hosts.iter().map(|h| h.addr.clone()).collect();
    let mut bal = spawn_balancer(&host_addrs);
    let mut client = ServiceClient::connect(&bal.addr).expect("chaos client connect");

    let mut sids: Vec<SessionId> = plan
        .tenants
        .iter()
        .map(|t| {
            client
                .open_session(t.cfg, t.d, t.seed, QosPolicy::unlimited())
                .unwrap_or_else(|e| panic!("seed {seed}: open failed: {e}"))
        })
        .collect();
    let mut observed_rounds = vec![0u64; plan.tenants.len()];

    for round in 0..plan.rounds {
        let mut churned: Option<(usize, bool)> = None;
        for fault in plan.at(round) {
            report.faults.push(fault.kind());
            match fault {
                Fault::KillHost { host } => {
                    let h = &mut hosts[*host];
                    assert!(h.alive, "seed {seed}: plan kills an already-dead host");
                    let mut killer = ServiceClient::connect(&h.addr).expect("killer connect");
                    killer.shutdown().unwrap_or_else(|e| panic!("seed {seed}: kill: {e}"));
                    h.handle
                        .take()
                        .expect("host handle")
                        .join()
                        .expect("host thread")
                        .expect("killed host exits cleanly");
                    h.alive = false;
                }
                Fault::ReviveHost { host } => {
                    let addr = hosts[*host].addr.clone();
                    assert!(!hosts[*host].alive, "seed {seed}: plan revives a live host");
                    hosts[*host] = spawn_host(&addr);
                    // Give the health cadence room to notice the
                    // dead→alive flip and reconcile; correctness must
                    // not depend on it (request-driven fail-over covers
                    // the gap), but most schedules should exercise the
                    // reconcile path itself.
                    std::thread::sleep(HEALTH_EVERY * 3);
                }
                Fault::RestartBalancer => {
                    bal.stopper.stop();
                    bal.handle.join().expect("balancer thread").expect("balancer stops cleanly");
                    bal = spawn_balancer(&host_addrs);
                    client = ServiceClient::connect(&bal.addr).expect("reconnect after restart");
                    // The rebuilt table hands out fresh client ids:
                    // re-discover ours by (cfg, d, seed) — and check
                    // the rebuilt restore points match every round the
                    // old balancer acknowledged to us.
                    let listed = match client.call(&Request::SessionList) {
                        Ok(Response::Sessions(r)) => r.sessions,
                        other => panic!("seed {seed}: session list after restart: {other:?}"),
                    };
                    for (t, tenant) in plan.tenants.iter().enumerate() {
                        let entry = listed
                            .iter()
                            .find(|e| {
                                e.snapshot.cfg == tenant.cfg
                                    && e.snapshot.d == tenant.d
                                    && e.snapshot.seed == tenant.seed
                            })
                            .unwrap_or_else(|| {
                                panic!("seed {seed}: tenant {t} lost across balancer restart")
                            });
                        assert_eq!(
                            entry.snapshot.rounds, observed_rounds[t],
                            "seed {seed}: rebuilt restore point disagrees with \
                             client-observed rounds for tenant {t}"
                        );
                        sids[t] = entry.session;
                    }
                }
                Fault::PoisonShard { host, shard } => {
                    if hosts[*host].alive {
                        hosts[*host].frontend.kill_shard(*shard);
                    }
                }
                Fault::CorruptHeader => inject_corrupt_header(&bal.addr),
                Fault::CorruptPayload => inject_corrupt_payload(&bal.addr),
                Fault::TruncateFrame => inject_truncated_frame(&bal.addr),
                Fault::DuplicateStats => {
                    let first = client.stats(None).expect("first stats read");
                    let second = client.stats(None).expect("duplicate stats read");
                    assert!(
                        second.rounds_run >= first.rounds_run,
                        "seed {seed}: duplicated stats read went backwards \
                         ({} then {})",
                        first.rounds_run,
                        second.rounds_run
                    );
                }
                Fault::DelayRound { ms } => std::thread::sleep(Duration::from_millis(*ms)),
                Fault::ChurnRound { tenant, below_threshold } => {
                    churned = Some((*tenant, *below_threshold));
                }
            }
        }

        for (t, tenant) in plan.tenants.iter().enumerate() {
            let signs =
                round_signs(plan.seed, t, round, tenant.cfg.n, tenant.d, tenant.cfg.precision);
            match churned {
                Some((ct, below)) if ct == t => {
                    let mask = churn_mask(tenant.cfg, below);
                    if below {
                        let n1 = tenant.cfg.n1();
                        let required = group_threshold(n1) + 1;
                        match client.submit_round_present(sids[t], &signs, &mask) {
                            Err(Error::Admission(AdmissionError::ChurnBelowThreshold {
                                group: 0,
                                survivors,
                                required: r,
                            })) if survivors == required - 1 && r == required => {
                                report.typed_aborts += 1;
                            }
                            other => panic!(
                                "seed {seed}: tenant {t} round {round}: expected a typed \
                                 below-threshold abort, got {other:?}"
                            ),
                        }
                    } else {
                        let vote = client
                            .submit_round_present(sids[t], &signs, &mask)
                            .unwrap_or_else(|e| {
                                panic!("seed {seed}: tenant {t} churn round {round}: {e}")
                            });
                        let set = ParticipantSet::from_mask(mask);
                        assert_eq!(
                            vote.global_vote,
                            plain_quant_aggregate_present(&signs, &set, tenant.cfg),
                            "seed {seed}: tenant {t} round {round}: churn vote diverged"
                        );
                        report.votes_checked += 1;
                        observed_rounds[t] += 1;
                    }
                }
                _ => {
                    let vote = client.submit_round(sids[t], &signs).unwrap_or_else(|e| {
                        panic!("seed {seed}: tenant {t} round {round}: {e}")
                    });
                    assert_eq!(
                        vote.global_vote,
                        plain_quant_aggregate(&signs, tenant.cfg),
                        "seed {seed}: tenant {t} round {round}: vote diverged from the \
                         plaintext reference"
                    );
                    assert_eq!(vote.session, sids[t], "replies carry the client's id");
                    report.votes_checked += 1;
                    observed_rounds[t] += 1;
                }
            }
        }
    }

    // Every restore is continuous and every displaced session counted
    // once: the cluster-wide round total equals exactly what this
    // client observed, no matter which hosts died under it.
    let total: u64 = observed_rounds.iter().sum();
    let stats = client.stats(None).expect("final cluster stats");
    assert_eq!(
        stats.rounds_run, total,
        "seed {seed}: cluster stats lost or double-counted rounds across the schedule"
    );

    for (t, &sid) in sids.iter().enumerate() {
        let snap = client
            .snapshot_session(sid)
            .unwrap_or_else(|e| panic!("seed {seed}: tenant {t} snapshot: {e}"));
        assert_eq!(snap.rounds, observed_rounds[t], "seed {seed}: restore point drifted");
        client
            .close_session(sid)
            .unwrap_or_else(|e| panic!("seed {seed}: tenant {t} close: {e}"));
    }

    // Zero leaked sessions, everywhere: the balancer's table is empty
    // and every host drains (reconciliation discards are asynchronous,
    // so poll briefly instead of asserting an instant).
    match client.call(&Request::SessionList) {
        Ok(Response::Sessions(r)) => assert!(
            r.sessions.is_empty(),
            "seed {seed}: balancer leaked sessions: {:?}",
            r.sessions.iter().map(|e| e.session).collect::<Vec<_>>()
        ),
        other => panic!("seed {seed}: final session list: {other:?}"),
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    for (i, host) in hosts.iter().enumerate() {
        loop {
            let live = host.frontend.live_sessions();
            if live == 0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "seed {seed}: host {i} leaked {live} session(s) after close + reconcile"
            );
            std::thread::sleep(HEALTH_EVERY);
        }
    }

    // A wedged pump cannot ack this shutdown or join cleanly — the
    // clean cluster-wide teardown is the no-wedge assertion.
    client.shutdown().expect("cluster shutdown acked");
    bal.handle.join().expect("balancer thread").expect("balancer clean exit");
    for (i, host) in hosts.iter_mut().enumerate() {
        host.handle
            .take()
            .expect("host handle")
            .join()
            .unwrap_or_else(|e| panic!("seed {seed}: host {i} thread: {e:?}"))
            .unwrap_or_else(|e| panic!("seed {seed}: host {i} dirty exit: {e}"));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_pure_functions_of_the_seed() {
        for seed in 0..64 {
            let a = FaultPlan::from_seed(seed);
            let b = FaultPlan::from_seed(seed);
            assert_eq!(a.rounds, b.rounds);
            assert_eq!(a.schedule, b.schedule);
            assert_eq!(
                a.tenants.iter().map(|t| (t.cfg, t.d, t.seed)).collect::<Vec<_>>(),
                b.tenants.iter().map(|t| (t.cfg, t.d, t.seed)).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn plans_keep_the_cluster_recoverable() {
        for seed in 0..256 {
            let plan = FaultPlan::from_seed(seed);
            let kills: Vec<(u64, usize)> = plan
                .schedule
                .iter()
                .filter_map(|(r, f)| match f {
                    Fault::KillHost { host } => Some((*r, *host)),
                    _ => None,
                })
                .collect();
            let revives: Vec<(u64, usize)> = plan
                .schedule
                .iter()
                .filter_map(|(r, f)| match f {
                    Fault::ReviveHost { host } => Some((*r, *host)),
                    _ => None,
                })
                .collect();
            assert_eq!(kills.len(), 1, "exactly one kill per plan");
            assert_eq!(revives.len(), 1, "exactly one revive per plan");
            assert_eq!(kills[0].1, revives[0].1, "the killed host is the revived one");
            assert!(kills[0].0 <= revives[0].0, "kill precedes revive");
            assert!(revives[0].0 < plan.rounds, "at least one round after the revive");
            // Poison only ever lands on the non-victim, which the plan
            // keeps alive throughout.
            for (_, fault) in &plan.schedule {
                if let Fault::PoisonShard { host, .. } = fault {
                    assert_ne!(*host, kills[0].1, "poison targets a live host");
                }
            }
            // Tenants are distinguishable after a balancer rebuild.
            assert_ne!(plan.tenants[0].seed, plan.tenants[1].seed);
            // Every plan exercises the quantized path: at least one
            // tenant runs at q > 2, and every precision is supported.
            assert!(
                plan.tenants.iter().any(|t| t.cfg.precision > 2),
                "seed {seed}: plan drew no q > 2 tenant"
            );
            for t in &plan.tenants {
                crate::quant::validate_precision(t.cfg.precision);
            }
        }
    }

    #[test]
    fn churn_masks_hit_both_sides_of_the_threshold() {
        for cfg in [
            HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit),
            HiSafeConfig::hierarchical(4, 2, TiePolicy::OneBit),
            HiSafeConfig::flat(3, TiePolicy::OneBit),
            HiSafeConfig::flat(4, TiePolicy::OneBit),
        ] {
            let n1 = cfg.n1();
            let required = group_threshold(n1) + 1;
            let ok = churn_mask(cfg, false);
            let starved = churn_mask(cfg, true);
            let g0_ok = ok.iter().take(n1).filter(|&&m| m).count();
            let g0_starved = starved.iter().take(n1).filter(|&&m| m).count();
            assert!(g0_ok >= required, "above-threshold mask must stay reconstructible");
            assert_eq!(g0_starved, required - 1, "starved mask is one short exactly");
        }
    }
}
