//! `AggFrontend` — a sharded front-end over many [`AggScheduler`]s,
//! speaking exactly the wire protocol of [`super::proto`].
//!
//! One [`AggScheduler`] is one process-local scheduling domain: one
//! worker pool, one provisioning plane. The frontend scales *out* by
//! owning `K` of them as **shards** and placing every tenant on one:
//!
//! ```text
//!            Request (proto.rs)            Response (proto.rs)
//!                  │                              ▲
//!                  ▼                              │
//!   ┌──────────── AggFrontend (this file) ────────┴─┐
//!   │  session table: external id → (shard, session) │
//!   │  placement: rendezvous hash on (cfg, d, seed)  │
//!   │             + least-loaded spill-over          │
//!   └──┬───────────────┬───────────────┬────────────┘
//!   shard 0         shard 1         shard K−1
//!   AggScheduler    AggScheduler    AggScheduler
//!   (pool+plane)    (pool+plane)    (pool+plane)
//! ```
//!
//! The frontend exposes **only** the request/response protocol
//! ([`AggFrontend::handle`]) — no caller reaches an engine directly —
//! so the same façade serves in-process embedding and the TCP server in
//! [`super::server`] unchanged, and everything a remote client can do
//! is exactly what a local one can.
//!
//! # Placement
//!
//! Tenants are placed by **rendezvous (highest-random-weight) hashing**
//! of the tenant key `(cfg, d, seed)`: every shard gets a deterministic
//! pseudo-random score for the key, and the highest score wins
//! ([`rendezvous_rank`], a pure unit-tested function). Rendezvous gives
//! the two properties a stateless balancer wants (and is why multiple
//! front-end processes pointing at the same shard set would agree):
//!
//! * **Balance**: keys spread uniformly — over many tenants each of `K`
//!   shards gets ~`1/K` of them (pinned within ±20% by the tests).
//! * **Minimal disruption**: adding or removing one shard only moves
//!   the ~`1/K` of keys whose winner changed, and growing `K` only ever
//!   moves keys *onto* the new shard (also pinned by tests).
//!
//! If the winning shard refuses admission (at its tenant cap), the
//! frontend **spills over** to the remaining shards in least-loaded
//! order — capacity pressure degrades placement locality, never
//! availability. [`AdmissionError::Rejected`] is returned only when
//! every shard refuses.
//!
//! # Drain and rebalance
//!
//! A shard can be **drained** ([`AggFrontend::drain_shard`]): it stops
//! receiving new tenants (rendezvous skips it, so its keys spill to
//! their next-ranked shard — the same set they'd map to if the shard
//! were removed), while existing sessions keep running. On
//! `SessionClose` the frontend retires the shard's scheduler as soon as
//! its last tenant leaves, tearing down its worker pool and dealing
//! plane; [`AggFrontend::undrain_shard`] returns it to rotation
//! (schedulers are created lazily, so a drained-then-reused shard just
//! respawns its infrastructure). This is the knob for rotating capacity
//! out of a live frontend without dropping a single round.
//!
//! # Determinism
//!
//! Placement never affects votes: a session's triple streams are pure
//! functions of its own `(seed, group)` (see `engine/scheduler.rs`),
//! so which shard a tenant lands on — like which tenants it shares a
//! plane with — changes wall-clock behavior only. The service property
//! tests pin remote votes bit-identical to in-process engines across
//! random shard counts.

use std::collections::BTreeMap;

use crate::engine::{AdmissionError, AggScheduler, AggSession, Engine, QosPolicy};
use crate::metrics::AdmissionStats;
use crate::protocol::HiSafeConfig;

use super::proto::{AdmissionReply, Request, Response, StatsReply, VoteReply};

/// SplitMix64 finalizer: a full-avalanche 64-bit mixer (public-domain
/// constants from Steele et al.), the hash primitive for rendezvous
/// scoring. Zero-dependency like the rest of the crate.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Fold a tenant's identity `(cfg, d, seed)` into the 64-bit placement
/// key. Every field participates, so two tenants differing only in tie
/// policy (or only in seed) hash independently.
pub(crate) fn tenant_key(cfg: &HiSafeConfig, d: usize, seed: u64) -> u64 {
    let mut h = splitmix64(seed);
    h = splitmix64(h ^ cfg.n as u64);
    h = splitmix64(h ^ cfg.ell as u64);
    h = splitmix64(h ^ cfg.intra.downlink_bits() as u64);
    h = splitmix64(h ^ ((cfg.inter.downlink_bits() as u64) << 8));
    h = splitmix64(h ^ ((cfg.sparse as u64) << 16));
    splitmix64(h ^ d as u64)
}

/// Rendezvous ranking: shards ordered by descending score
/// `splitmix64(key ⊕ splitmix64(shard))`. Index 0 is the placement
/// winner; the rest is the deterministic fail-over order. Each shard's
/// score depends only on `(key, shard)` — never on `shards` — which is
/// what makes the ranking stable under shard-count changes (the
/// rendezvous property the tests pin).
pub(crate) fn rendezvous_rank(key: u64, shards: usize) -> Vec<usize> {
    let mut scored: Vec<(u64, usize)> = (0..shards)
        .map(|i| (splitmix64(key ^ splitmix64(i as u64 ^ 0x5bd1_e995)), i))
        .collect();
    // Descending by score; scores collide with probability ~2⁻⁶⁴, and
    // the index tie-break keeps even that case deterministic.
    scored.sort_unstable_by_key(|&(score, i)| std::cmp::Reverse((score, i)));
    scored.into_iter().map(|(_, i)| i).collect()
}

/// One scheduler shard. The scheduler itself is lazy: spawned on first
/// placement, retired when a drained shard empties — so idle shards
/// cost no threads.
struct Shard {
    sched: Option<AggScheduler>,
    /// Worker threads to spawn this shard's pool with.
    threads: usize,
    /// Per-shard tenant cap (`AggScheduler::with_capacity`).
    max_tenants: Option<usize>,
    /// Live sessions placed here (frontend-side count; the scheduler's
    /// own `live_tenants` agrees, but this survives `sched = None`).
    tenants: usize,
    /// Draining shards receive no new placements.
    draining: bool,
}

impl Shard {
    fn sched(&mut self) -> &AggScheduler {
        self.sched.get_or_insert_with(|| match self.max_tenants {
            Some(cap) => AggScheduler::with_capacity(self.threads, cap),
            None => AggScheduler::with_threads(self.threads),
        })
    }
}

/// A live session and the shard that owns it.
struct FrontSession {
    shard: usize,
    session: AggSession,
}

/// Service-level ceilings on wire-controlled sizes. The engine asserts
/// (panics) on shapes it was never built for and will happily allocate
/// whatever a caller asks — correct for in-process callers, fatal for a
/// server whose mutex a panic would poison. These are generous bounds
/// (orders of magnitude above the paper's operating points — n ≤ 100,
/// d ≈ 7.8k) that stop abuse without constraining use.
const MAX_USERS: usize = 4096;
const MAX_DIM: usize = 1 << 22;
const MAX_PREFETCH_ROUNDS: usize = 4096;

/// Reject wire shapes the engine cannot serve *before* they reach its
/// asserting surface: a panic on a connection thread would poison the
/// frontend mutex and take down every session (the contract is typed
/// rejections for malformed content, panics only for internal bugs).
fn validate_shape(cfg: &HiSafeConfig, d: usize) -> Result<(), AdmissionError> {
    let bad = |reason: String| Err(AdmissionError::Rejected { reason });
    if cfg.n == 0 || cfg.ell == 0 {
        return bad(format!("n = {} and ell = {} must both be >= 1", cfg.n, cfg.ell));
    }
    if cfg.n % cfg.ell != 0 {
        return bad(format!("ell = {} must divide n = {}", cfg.ell, cfg.n));
    }
    if cfg.n > MAX_USERS {
        return bad(format!("n = {} exceeds the service cap of {MAX_USERS} users", cfg.n));
    }
    if d == 0 || d > MAX_DIM {
        return bad(format!("d = {d} must be in [1, {MAX_DIM}]"));
    }
    Ok(())
}

/// The sharded service front-end: owns `K` scheduler shards and a
/// session table, and answers wire-protocol [`Request`]s. See the
/// module docs for placement and drain semantics.
///
/// ```
/// use hisafe::engine::QosPolicy;
/// use hisafe::poly::TiePolicy;
/// use hisafe::protocol::HiSafeConfig;
/// use hisafe::service::{AggFrontend, Request, Response};
///
/// let mut fe = AggFrontend::new(2, 1);
/// let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
/// let open = Request::SessionOpen { cfg, d: 4, seed: 7, qos: QosPolicy::unlimited() };
/// let sid = match fe.handle(&open) {
///     Response::Admission(r) => r.session.expect("granted"),
///     other => panic!("unexpected reply: {other:?}"),
/// };
/// let signs = vec![vec![1i8, -1, 1, -1]; 6];
/// match fe.handle(&Request::RoundSubmit { session: sid, signs }) {
///     Response::Vote(v) => assert_eq!(v.global_vote, vec![1, -1, 1, -1]),
///     other => panic!("unexpected reply: {other:?}"),
/// }
/// ```
pub struct AggFrontend {
    shards: Vec<Shard>,
    sessions: BTreeMap<u64, FrontSession>,
    next_session: u64,
    /// Fold of closed sessions' admission counters, so frontend-wide
    /// stats survive tenant churn.
    closed_admission: AdmissionStats,
    /// Ditto for rounds run / dealt by closed sessions.
    closed_rounds_run: u64,
    closed_dealt: u64,
}

impl AggFrontend {
    /// A frontend over `shards` scheduler shards, each spawning
    /// `threads_per_shard` span workers (plus its dealer thread) lazily
    /// on first placement. No per-shard tenant cap.
    pub fn new(shards: usize, threads_per_shard: usize) -> AggFrontend {
        Self::build(shards, threads_per_shard, None)
    }

    /// Like [`new`](AggFrontend::new), but every shard refuses more than
    /// `max_tenants_per_shard` concurrent sessions — the placement layer
    /// then spills to the least-loaded shard, and `SessionOpen` is
    /// `Rejected` only when the whole frontend is full.
    pub fn with_shard_capacity(
        shards: usize,
        threads_per_shard: usize,
        max_tenants_per_shard: usize,
    ) -> AggFrontend {
        Self::build(shards, threads_per_shard, Some(max_tenants_per_shard))
    }

    fn build(shards: usize, threads: usize, max_tenants: Option<usize>) -> AggFrontend {
        assert!(shards >= 1, "a frontend needs at least one shard");
        assert!(threads >= 1, "shards need at least one worker thread");
        AggFrontend {
            shards: (0..shards)
                .map(|_| Shard {
                    sched: None,
                    threads,
                    max_tenants,
                    tenants: 0,
                    draining: false,
                })
                .collect(),
            sessions: BTreeMap::new(),
            next_session: 0,
            closed_admission: AdmissionStats::default(),
            closed_rounds_run: 0,
            closed_dealt: 0,
        }
    }

    /// Number of scheduler shards (fixed at construction).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Live sessions per shard (frontend-side placement counts).
    pub fn shard_tenants(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.tenants).collect()
    }

    /// Total live sessions across every shard.
    pub fn live_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Stop placing new tenants on shard `i`; its keys spill to their
    /// next-ranked shard exactly as if the shard were removed. Existing
    /// sessions keep running; the shard's scheduler (pool + plane) is
    /// retired as soon as its last session closes.
    ///
    /// # Panics
    ///
    /// If `i` is out of range, or if draining `i` would leave no shard
    /// accepting placements.
    pub fn drain_shard(&mut self, i: usize) {
        assert!(i < self.shards.len(), "shard {i} out of range");
        assert!(
            self.shards.iter().enumerate().any(|(k, s)| k != i && !s.draining),
            "cannot drain the last accepting shard"
        );
        self.shards[i].draining = true;
        self.retire_if_drained(i);
    }

    /// Return a drained shard to the placement rotation (its scheduler
    /// respawns lazily on the next placement).
    ///
    /// # Panics
    ///
    /// If `i` is out of range.
    pub fn undrain_shard(&mut self, i: usize) {
        assert!(i < self.shards.len(), "shard {i} out of range");
        self.shards[i].draining = false;
    }

    /// Whether shard `i` currently holds live scheduler infrastructure
    /// (a worker pool + dealing plane). False until first placement and
    /// again after a drain empties it.
    pub fn shard_is_live(&self, i: usize) -> bool {
        self.shards[i].sched.is_some()
    }

    /// The rebalance step: a draining shard with no tenants left drops
    /// its scheduler handle, tearing down its threads. (Sessions hold
    /// the scheduler core alive through their own `Arc`s, so this is
    /// safe even mid-race with a closing session.)
    fn retire_if_drained(&mut self, i: usize) {
        let s = &mut self.shards[i];
        if s.draining && s.tenants == 0 {
            s.sched = None;
        }
    }

    /// Place a tenant: rendezvous winner first, then least-loaded
    /// spill-over among the remaining accepting shards.
    fn place(
        &mut self,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
        qos: QosPolicy,
    ) -> Result<u64, AdmissionError> {
        // Validate shape and policy up front: both must be the same
        // typed rejection on every shard (and must never reach the
        // engine's asserting surface), so don't let either consume a
        // placement attempt (the shard re-validates the policy anyway).
        validate_shape(&cfg, d)?;
        qos.validate()?;
        let rank = rendezvous_rank(tenant_key(&cfg, d, seed), self.shards.len());
        let mut candidates: Vec<usize> =
            rank.iter().copied().filter(|&i| !self.shards[i].draining).collect();
        if candidates.is_empty() {
            return Err(AdmissionError::Rejected {
                reason: "every shard is draining".into(),
            });
        }
        // Keep the rendezvous winner in front; order the spill-over
        // candidates by current load (stable sort preserves rendezvous
        // order among equally-loaded shards).
        let spill = candidates.split_off(1);
        let mut by_load = spill;
        by_load.sort_by_key(|&i| self.shards[i].tenants);
        candidates.extend(by_load);

        let mut last_err = None;
        for i in candidates {
            match self.shards[i].sched().try_session(cfg, d, seed, qos) {
                Ok(session) => {
                    let sid = self.next_session;
                    self.next_session += 1;
                    self.shards[i].tenants += 1;
                    self.sessions.insert(sid, FrontSession { shard: i, session });
                    return Ok(sid);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one candidate shard was tried"))
    }

    /// Answer one wire-protocol request. Never panics on malformed
    /// *content* (unknown sessions, wrong sign-matrix shapes, invalid
    /// policies all come back as typed [`AdmissionReply`] denials) —
    /// panicking is reserved for frontend-internal invariant breaks.
    pub fn handle(&mut self, req: &Request) -> Response {
        match req {
            Request::SessionOpen { cfg, d, seed, qos } => match self.place(*cfg, *d, *seed, *qos)
            {
                Ok(sid) => Response::Admission(AdmissionReply::ok(Some(sid))),
                Err(e) => Response::Admission(AdmissionReply::denied(None, e)),
            },
            Request::RoundSubmit { session, signs } => {
                let Some(fs) = self.sessions.get_mut(session) else {
                    return unknown_session(*session);
                };
                // Shape-check before the engine surface: the engine
                // asserts (panics) on bad shapes, which is right for
                // in-process bugs but must be a typed rejection for
                // wire input.
                let (n, d) = (fs.session.config().n, fs.session.dim());
                if signs.len() != n || signs.iter().any(|s| s.len() != d) {
                    return Response::Admission(AdmissionReply::denied(
                        Some(*session),
                        AdmissionError::Rejected {
                            reason: format!(
                                "sign matrix must be {n} users x {d} coordinates"
                            ),
                        },
                    ));
                }
                match fs.session.try_run_round(signs) {
                    Ok(out) => Response::Vote(VoteReply {
                        session: *session,
                        global_vote: out.global_vote,
                        subgroup_votes: out.subgroup_votes,
                        stats: out.stats,
                    }),
                    Err(e) => Response::Admission(AdmissionReply::denied(Some(*session), e)),
                }
            }
            Request::Prefetch { session, rounds } => {
                let Some(fs) = self.sessions.get_mut(session) else {
                    return unknown_session(*session);
                };
                // Bound per-call dealing work: with an unbounded queue
                // depth (the tenant's own choice), a single wire request
                // could otherwise queue effectively infinite dealing.
                if *rounds > MAX_PREFETCH_ROUNDS {
                    return Response::Admission(AdmissionReply::denied(
                        Some(*session),
                        AdmissionError::Rejected {
                            reason: format!(
                                "prefetch of {rounds} rounds exceeds the service cap of \
                                 {MAX_PREFETCH_ROUNDS} per call"
                            ),
                        },
                    ));
                }
                match fs.session.try_prefetch(*rounds) {
                    Ok(()) => Response::Admission(AdmissionReply::ok(Some(*session))),
                    Err(e) => Response::Admission(AdmissionReply::denied(Some(*session), e)),
                }
            }
            Request::SessionClose { session } => {
                let Some(fs) = self.sessions.remove(session) else {
                    return unknown_session(*session);
                };
                self.closed_admission.merge(&fs.session.admission_stats());
                self.closed_rounds_run += fs.session.rounds_run();
                self.closed_dealt += fs.session.dealt_rounds();
                let shard = fs.shard;
                drop(fs); // deregisters from the shard's plane
                self.shards[shard].tenants -= 1;
                self.retire_if_drained(shard);
                Response::Admission(AdmissionReply::ok(Some(*session)))
            }
            Request::StatsQuery { session: Some(sid) } => {
                let Some(fs) = self.sessions.get(sid) else {
                    return unknown_session(*sid);
                };
                Response::Stats(StatsReply {
                    session: Some(*sid),
                    shard: Some(fs.shard),
                    rounds_run: fs.session.rounds_run(),
                    dealt_rounds: fs.session.dealt_rounds(),
                    admission: fs.session.admission_stats(),
                    shard_tenants: None,
                })
            }
            Request::StatsQuery { session: None } => {
                let live: Vec<AdmissionStats> =
                    self.sessions.values().map(|fs| fs.session.admission_stats()).collect();
                let mut admission = AdmissionStats::merge_all(live.iter());
                admission.merge(&self.closed_admission);
                let rounds_run = self.closed_rounds_run
                    + self.sessions.values().map(|fs| fs.session.rounds_run()).sum::<u64>();
                let dealt_rounds = self.closed_dealt
                    + self.sessions.values().map(|fs| fs.session.dealt_rounds()).sum::<u64>();
                Response::Stats(StatsReply {
                    session: None,
                    shard: None,
                    rounds_run,
                    dealt_rounds,
                    admission,
                    shard_tenants: Some(self.shard_tenants()),
                })
            }
            // The frontend just acks; stopping the accept loop is the
            // transport layer's job (see `service::server`).
            Request::Shutdown => Response::Admission(AdmissionReply::ok(None)),
        }
    }
}

fn unknown_session(sid: u64) -> Response {
    Response::Admission(AdmissionReply::denied(
        Some(sid),
        AdmissionError::Rejected { reason: format!("unknown session {sid}") },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::TiePolicy;
    use crate::protocol::plain_hierarchical_vote;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn open(fe: &mut AggFrontend, cfg: HiSafeConfig, d: usize, seed: u64) -> u64 {
        match fe.handle(&Request::SessionOpen { cfg, d, seed, qos: QosPolicy::unlimited() }) {
            Response::Admission(AdmissionReply { session: Some(sid), error: None }) => sid,
            other => panic!("expected a session grant, got {other:?}"),
        }
    }

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    /// 2k synthetic tenant keys for the placement-distribution tests
    /// (enough that a ±20% balance bound sits ≥ 4.5σ from the binomial
    /// mean — the fixed seed makes the test deterministic, the margin
    /// makes the fixed draw virtually certain to be a typical one).
    fn synthetic_keys() -> Vec<u64> {
        let mut rng = Xoshiro256pp::seed_from_u64(0x5a4d);
        (0..2000)
            .map(|i| {
                let cfg = HiSafeConfig::hierarchical(
                    6 * (1 + (i % 4)),
                    1 + (i % 4),
                    if i % 2 == 0 { TiePolicy::OneBit } else { TiePolicy::TwoBit },
                );
                tenant_key(&cfg, 64 + i, rng.next_u64())
            })
            .collect()
    }

    #[test]
    fn rendezvous_rank_is_deterministic_and_a_permutation() {
        for key in [0u64, 1, 0xdead_beef, u64::MAX] {
            for shards in [1usize, 2, 7, 16] {
                let a = rendezvous_rank(key, shards);
                let b = rendezvous_rank(key, shards);
                assert_eq!(a, b, "same key must rank identically");
                let mut sorted = a.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..shards).collect::<Vec<_>>(), "must be a permutation");
            }
        }
    }

    #[test]
    fn rendezvous_balances_synthetic_tenants_within_20pct() {
        let keys = synthetic_keys();
        for shards in [4usize, 5] {
            let mut counts = vec![0usize; shards];
            for &key in &keys {
                counts[rendezvous_rank(key, shards)[0]] += 1;
            }
            let expect = keys.len() / shards;
            for (i, &c) in counts.iter().enumerate() {
                assert!(
                    (c as f64) >= expect as f64 * 0.8 && (c as f64) <= expect as f64 * 1.2,
                    "shard {i}/{shards} got {c} of {} tenants (expected {expect} +/- 20%)",
                    keys.len()
                );
            }
        }
    }

    #[test]
    fn rendezvous_is_stable_under_shard_count_change() {
        // Growing K -> K+1 must move only the ~1/(K+1) of keys whose
        // winner is the NEW shard — and every moved key must move to it.
        let keys = synthetic_keys();
        for k in [4usize, 8] {
            let mut moved = 0usize;
            for &key in &keys {
                let before = rendezvous_rank(key, k)[0];
                let after = rendezvous_rank(key, k + 1)[0];
                if before != after {
                    moved += 1;
                    assert_eq!(
                        after, k,
                        "key {key:#x}: grew {k}->{} but moved to old shard {after}",
                        k + 1
                    );
                }
            }
            let expect = keys.len() / (k + 1);
            assert!(
                moved <= expect * 2 && moved >= expect / 2,
                "K={k}: {moved} of {} keys moved (expected ~{expect})",
                keys.len()
            );
            // Shrinking is the same statement read backwards: keys on
            // surviving shards stay put. (Already implied, but state it.)
            for &key in keys.iter().take(50) {
                let big = rendezvous_rank(key, k + 1)[0];
                if big != k {
                    assert_eq!(rendezvous_rank(key, k)[0], big);
                }
            }
        }
    }

    #[test]
    fn frontend_votes_match_plain_reference_across_shards() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut fe = AggFrontend::new(3, 1);
        let sids: Vec<u64> = (0..4).map(|i| open(&mut fe, cfg, 5, 100 + i)).collect();
        assert_eq!(fe.live_sessions(), 4);
        for r in 0..2u64 {
            for (i, &sid) in sids.iter().enumerate() {
                let signs = rand_signs(6, 5, 7 + r * 10 + i as u64);
                match fe.handle(&Request::RoundSubmit { session: sid, signs: signs.clone() }) {
                    Response::Vote(v) => {
                        assert_eq!(v.global_vote, plain_hierarchical_vote(&signs, cfg));
                        assert_eq!(v.session, sid);
                    }
                    other => panic!("expected a vote, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn malformed_session_shapes_are_rejected_not_panics() {
        // A wire SessionOpen with a config the engine would assert on
        // (ell = 0, ell not dividing n, n = 0) — or absurd sizes — must
        // be a typed rejection. A panic here would poison the server's
        // frontend mutex and kill every live session.
        let mut fe = AggFrontend::new(2, 1);
        let ok = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        for (cfg, d) in [
            (HiSafeConfig { ell: 0, ..ok }, 4),                  // ell = 0
            (HiSafeConfig { n: 5, ell: 2, ..ok }, 4),            // ell does not divide n
            (HiSafeConfig { n: 0, ell: 1, ..ok }, 4),            // no users
            (HiSafeConfig { n: MAX_USERS + 1, ell: 1, ..ok }, 4), // over the user cap
            (ok, 0),                                             // d = 0
            (ok, MAX_DIM + 1),                                   // over the dim cap
        ] {
            match fe.handle(&Request::SessionOpen { cfg, d, seed: 1, qos: QosPolicy::unlimited() })
            {
                Response::Admission(AdmissionReply {
                    error: Some(AdmissionError::Rejected { .. }),
                    ..
                }) => {}
                other => panic!("cfg={cfg:?} d={d} must be rejected, got {other:?}"),
            }
        }
        assert_eq!(fe.live_sessions(), 0);
        // Oversized prefetch requests are capped per call, not executed.
        let sid = open(&mut fe, ok, 5, 1);
        match fe.handle(&Request::Prefetch { session: sid, rounds: MAX_PREFETCH_ROUNDS + 1 }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("service cap"), "reason: {reason}"),
            other => panic!("expected a prefetch cap rejection, got {other:?}"),
        }
    }

    #[test]
    fn malformed_round_shapes_are_rejected_not_panics() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut fe = AggFrontend::new(1, 1);
        let sid = open(&mut fe, cfg, 5, 1);
        // Wrong user count and wrong dimension both come back typed.
        for signs in [rand_signs(5, 5, 2), rand_signs(6, 4, 3)] {
            match fe.handle(&Request::RoundSubmit { session: sid, signs }) {
                Response::Admission(AdmissionReply {
                    error: Some(AdmissionError::Rejected { reason }),
                    ..
                }) => assert!(reason.contains("sign matrix"), "reason: {reason}"),
                other => panic!("expected a shape rejection, got {other:?}"),
            }
        }
        // Unknown sessions likewise.
        match fe.handle(&Request::RoundSubmit { session: 999, signs: rand_signs(6, 5, 4) }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("unknown session"), "reason: {reason}"),
            other => panic!("expected unknown-session, got {other:?}"),
        }
    }

    #[test]
    fn capacity_spill_over_prefers_least_loaded_then_rejects_when_full() {
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut fe = AggFrontend::with_shard_capacity(2, 1, 2);
        // 4 tenants fill both shards (2 each) regardless of rendezvous
        // preference, because capacity overflow spills.
        let _sids: Vec<u64> = (0..4).map(|i| open(&mut fe, cfg, 4, i)).collect();
        assert_eq!(fe.shard_tenants(), vec![2, 2]);
        // A 5th tenant has nowhere to go.
        match fe.handle(&Request::SessionOpen {
            cfg,
            d: 4,
            seed: 99,
            qos: QosPolicy::unlimited(),
        }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { .. }),
                ..
            }) => {}
            other => panic!("expected rejection at full capacity, got {other:?}"),
        }
    }

    #[test]
    fn drain_empties_and_retires_a_shard_then_undrain_restores_it() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut fe = AggFrontend::new(2, 1);
        // Open sessions until both shards hold at least one, remembering
        // every session's shard.
        let mut placed: Vec<(u64, usize)> = Vec::new();
        let mut seed = 0u64;
        while !(placed.iter().any(|&(_, s)| s == 0) && placed.iter().any(|&(_, s)| s == 1)) {
            let sid = open(&mut fe, cfg, 5, seed);
            let shard = match fe.handle(&Request::StatsQuery { session: Some(sid) }) {
                Response::Stats(s) => s.shard.unwrap(),
                other => panic!("expected stats, got {other:?}"),
            };
            placed.push((sid, shard));
            seed += 1;
            assert!(seed < 100, "rendezvous never covered both shards");
        }
        let drained = 0usize;
        fe.drain_shard(drained);
        assert!(fe.shard_is_live(drained), "live sessions keep the scheduler");
        // New tenants all land on the surviving shard.
        for s in 100..104u64 {
            let sid = open(&mut fe, cfg, 5, s);
            match fe.handle(&Request::StatsQuery { session: Some(sid) }) {
                Response::Stats(st) => assert_eq!(st.shard, Some(1)),
                other => panic!("expected stats, got {other:?}"),
            }
        }
        // The draining shard's sessions still run rounds.
        let on_drained: Vec<u64> =
            placed.iter().filter(|&&(_, s)| s == drained).map(|&(sid, _)| sid).collect();
        let signs = rand_signs(6, 5, 77);
        match fe.handle(&Request::RoundSubmit { session: on_drained[0], signs: signs.clone() }) {
            Response::Vote(v) => {
                assert_eq!(v.global_vote, plain_hierarchical_vote(&signs, cfg))
            }
            other => panic!("expected a vote, got {other:?}"),
        }
        // Closing its last session retires the shard's scheduler
        // (threads torn down); until then it stays live.
        for &sid in &on_drained {
            assert!(fe.shard_is_live(drained), "retire must wait for the last session");
            match fe.handle(&Request::SessionClose { session: sid }) {
                Response::Admission(AdmissionReply { error: None, .. }) => {}
                other => panic!("expected a close ack, got {other:?}"),
            }
        }
        assert!(!fe.shard_is_live(drained), "drained+empty shard must retire");
        // Undrain returns it to rotation; infrastructure respawns lazily.
        fe.undrain_shard(drained);
        let mut seed = 1000u64;
        loop {
            let sid = open(&mut fe, cfg, 5, seed);
            let shard = match fe.handle(&Request::StatsQuery { session: Some(sid) }) {
                Response::Stats(s) => s.shard.unwrap(),
                other => panic!("expected stats, got {other:?}"),
            };
            if shard == drained {
                break;
            }
            seed += 1;
            assert!(seed < 1100, "rendezvous never picked the undrained shard");
        }
        assert!(fe.shard_is_live(drained));
    }

    #[test]
    fn frontend_stats_merge_across_shards_and_survive_churn() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut fe = AggFrontend::new(2, 1);
        let a = open(&mut fe, cfg, 5, 1);
        let b = open(&mut fe, cfg, 5, 2);
        for r in 0..3u64 {
            for &sid in [a, b].iter() {
                let signs = rand_signs(6, 5, 50 + r);
                match fe.handle(&Request::RoundSubmit { session: sid, signs }) {
                    Response::Vote(_) => {}
                    other => panic!("expected a vote, got {other:?}"),
                }
            }
        }
        // Close one session: its counters must fold into the aggregate.
        fe.handle(&Request::SessionClose { session: a });
        match fe.handle(&Request::StatsQuery { session: None }) {
            Response::Stats(s) => {
                assert_eq!(s.session, None);
                assert_eq!(s.rounds_run, 6, "3 rounds from each of 2 sessions");
                assert_eq!(s.admission.admitted_rounds, 6);
                let tenants = s.shard_tenants.expect("frontend scope lists shards");
                assert_eq!(tenants.len(), 2);
                assert_eq!(tenants.iter().sum::<usize>(), 1, "one session still live");
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }
}
