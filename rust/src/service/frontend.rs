//! `AggFrontend` — a sharded front-end over many [`AggScheduler`]s,
//! speaking exactly the wire protocol of [`super::proto`].
//!
//! One [`AggScheduler`] is one process-local scheduling domain: one
//! worker pool, one provisioning plane. The frontend scales *out* by
//! owning `K` of them as **shards** and placing every tenant on one:
//!
//! ```text
//!            Request (proto.rs)            Response (proto.rs)
//!                  │                              ▲
//!                  ▼                              │
//!   ┌──────────── AggFrontend (this file) ────────┴─┐
//!   │  router: session id → (shard, restore meta)   │
//!   │  placement: rendezvous hash on (cfg, d, seed) │
//!   │             + least-loaded spill-over         │
//!   └──┬───────────────┬───────────────┬───────────┘
//!   shard 0         shard 1         shard K−1
//!   Mutex<state>    Mutex<state>    Mutex<state>
//!   AggScheduler    AggScheduler    AggScheduler
//!   (pool+plane)    (pool+plane)    (pool+plane)
//! ```
//!
//! The frontend exposes **only** the request/response protocol
//! ([`AggFrontend::handle`]) — no caller reaches an engine directly —
//! so the same façade serves in-process embedding and the TCP server in
//! [`super::server`] unchanged, and everything a remote client can do
//! is exactly what a local one can.
//!
//! # Per-shard locking
//!
//! [`AggFrontend::handle`] takes `&self`: the frontend is shared across
//! connection workers as a plain `Arc<AggFrontend>`, and each shard's
//! state sits behind its **own** mutex. A round on shard 0 never waits
//! for a round on shard 1 — `K` shards serve `K` wire rounds in
//! parallel (pinned by the concurrency test below and by the
//! `sched_remote` bench's multi-host mode). A small **router** mutex
//! guards only the session table (id → shard + restore metadata);
//! round execution holds exactly one shard lock and touches the router
//! only for O(1) map lookups before and after.
//!
//! Lock ordering: the router lock may be held while acquiring a shard
//! lock (restore does this), but a shard lock is **never** held while
//! acquiring the router or another shard — which is what makes the
//! locking deadlock-free by construction.
//!
//! # Shard death and transparent restore
//!
//! A panic on a connection worker while it holds a shard lock poisons
//! only that shard's mutex. The next thread to touch the shard absorbs
//! the poison, marks the shard **dead**, and discards its state: a
//! panicked round may have consumed a partial round of Beaver triples,
//! so the in-memory sessions can no longer be trusted to be
//! stream-aligned. Their tenants are *not* lost — the router keeps, for
//! every session, the [`SessionSnapshot`] ingredients `(cfg, d, seed,
//! qos, rounds-consumed)`, and the next request touching a displaced
//! session transparently resumes it on the next-ranked live shard via
//! [`AggScheduler::try_session_resumed`], which replays the dealer
//! stream to exactly the consumed-rounds boundary. Votes after a shard
//! death are bit-identical to an uninterrupted run (pinned by tests
//! here and in `tests/service_props.rs`). [`AggFrontend::kill_shard`]
//! is the operational/test hook that induces the same death path
//! without a panic.
//!
//! # Placement
//!
//! Tenants are placed by **rendezvous (highest-random-weight) hashing**
//! of the tenant key `(cfg, d, seed)`: every shard gets a deterministic
//! pseudo-random score for the key, and the highest score wins
//! ([`rendezvous_rank`], a pure unit-tested function). Rendezvous gives
//! the two properties a stateless balancer wants (and is why multiple
//! front-end processes pointing at the same shard set would agree):
//!
//! * **Balance**: keys spread uniformly — over many tenants each of `K`
//!   shards gets ~`1/K` of them (pinned within ±20% by the tests).
//! * **Minimal disruption**: adding or removing one shard only moves
//!   the ~`1/K` of keys whose winner changed, and growing `K` only ever
//!   moves keys *onto* the new shard (also pinned by tests).
//!
//! If the winning shard refuses admission (at its tenant cap), the
//! frontend **spills over** to the remaining shards in least-loaded
//! order — capacity pressure degrades placement locality, never
//! availability. [`AdmissionError::Rejected`] is returned only when
//! every shard refuses. Placement order is resolved *before* any lock
//! is taken (shard flags and load counters are atomics).
//!
//! # Drain and rebalance
//!
//! A shard can be **drained** ([`AggFrontend::drain_shard`]): it stops
//! receiving new tenants (rendezvous skips it, so its keys spill to
//! their next-ranked shard — the same set they'd map to if the shard
//! were removed), while existing sessions keep running. On
//! `SessionClose` the frontend retires the shard's scheduler as soon as
//! its last tenant leaves, tearing down its worker pool and dealing
//! plane; [`AggFrontend::undrain_shard`] returns it to rotation
//! (schedulers are created lazily, so a drained-then-reused shard just
//! respawns its infrastructure). This is the knob for rotating capacity
//! out of a live frontend without dropping a single round.
//!
//! # Determinism
//!
//! Placement never affects votes: a session's triple streams are pure
//! functions of its own `(seed, group)` (see `engine/scheduler.rs`),
//! so which shard a tenant lands on — like which tenants it shares a
//! plane with, or whether it was restored mid-stream after a shard
//! death — changes wall-clock behavior only. The service property tests
//! pin remote votes bit-identical to in-process engines across random
//! shard counts and mid-sweep shard kills.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::engine::{
    AdmissionError, AggScheduler, AggSession, Engine, QosPolicy, SessionId, SessionSnapshot,
};
use crate::metrics::AdmissionStats;
use crate::protocol::{HiSafeConfig, ParticipantSet};

use super::error::Error;
use super::proto::{
    AdmissionReply, Request, Response, SessionListReply, SnapshotReply, StatsReply, VoteReply,
};

/// SplitMix64 finalizer: a full-avalanche 64-bit mixer (public-domain
/// constants from Steele et al.), the hash primitive for rendezvous
/// scoring. Zero-dependency like the rest of the crate.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Fold a tenant's identity `(cfg, d, seed)` into the 64-bit placement
/// key. Every field participates, so two tenants differing only in tie
/// policy (or only in seed) hash independently.
pub(crate) fn tenant_key(cfg: &HiSafeConfig, d: usize, seed: u64) -> u64 {
    let mut h = splitmix64(seed);
    h = splitmix64(h ^ cfg.n as u64);
    h = splitmix64(h ^ cfg.ell as u64);
    h = splitmix64(h ^ cfg.intra.downlink_bits() as u64);
    h = splitmix64(h ^ ((cfg.inter.downlink_bits() as u64) << 8));
    h = splitmix64(h ^ ((cfg.sparse as u64) << 16));
    // Mixed only off the sign-vote default so every q = 2 tenant keeps
    // the exact pre-quant key (and therefore its shard/host placement).
    if cfg.precision != 2 {
        h = splitmix64(h ^ ((cfg.precision as u64) << 24));
    }
    splitmix64(h ^ d as u64)
}

/// Rendezvous ranking: shards ordered by descending score
/// `splitmix64(key ⊕ splitmix64(shard))`. Index 0 is the placement
/// winner; the rest is the deterministic fail-over order. Each shard's
/// score depends only on `(key, shard)` — never on `shards` — which is
/// what makes the ranking stable under shard-count changes (the
/// rendezvous property the tests pin). The balancer reuses the same
/// ranking across *hosts*, so host placement agrees with shard
/// placement by construction.
pub(crate) fn rendezvous_rank(key: u64, shards: usize) -> Vec<usize> {
    let mut scored: Vec<(u64, usize)> = (0..shards)
        .map(|i| (splitmix64(key ^ splitmix64(i as u64 ^ 0x5bd1_e995)), i))
        .collect();
    // Descending by score; scores collide with probability ~2⁻⁶⁴, and
    // the index tie-break keeps even that case deterministic.
    scored.sort_unstable_by_key(|&(score, i)| std::cmp::Reverse((score, i)));
    scored.into_iter().map(|(_, i)| i).collect()
}

/// The lock-guarded state of one scheduler shard. The scheduler itself
/// is lazy: spawned on first placement, retired when a drained shard
/// empties — so idle shards cost no threads. The sessions placed here
/// live in this map so round execution needs exactly this one lock.
struct ShardState {
    sched: Option<AggScheduler>,
    /// Worker threads to spawn this shard's pool with.
    threads: usize,
    /// Per-shard tenant cap (`AggScheduler::with_capacity`).
    max_tenants: Option<usize>,
    /// Live sessions placed on this shard.
    sessions: BTreeMap<SessionId, AggSession>,
}

impl ShardState {
    fn sched(&mut self) -> &AggScheduler {
        self.sched.get_or_insert_with(|| match self.max_tenants {
            Some(cap) => AggScheduler::with_capacity(self.threads, cap),
            None => AggScheduler::with_threads(self.threads),
        })
    }
}

/// One shard slot: the state mutex plus the lock-free flags placement
/// reads *before* locking anything.
struct ShardSlot {
    state: Mutex<ShardState>,
    /// Draining shards receive no new placements.
    draining: AtomicBool,
    /// Dead shards (absorbed lock poison, or
    /// [`AggFrontend::kill_shard`]) are skipped entirely; their sessions
    /// restore elsewhere on touch.
    dead: AtomicBool,
    /// Live placements, for least-loaded spill-over and
    /// [`AggFrontend::shard_tenants`] without taking the state lock.
    /// Mutated only while holding the state lock, so death-zeroing and
    /// place/close updates never interleave inconsistently.
    tenants: AtomicUsize,
}

/// What the router remembers about a session *besides* its live
/// [`AggSession`]: exactly the [`SessionSnapshot`] ingredients, so a
/// session whose shard died can be resumed bit-identically from
/// metadata alone.
#[derive(Clone)]
struct SessionMeta {
    cfg: HiSafeConfig,
    d: usize,
    seed: u64,
    qos: QosPolicy,
    /// Whole rounds consumed — incremented only after a round's vote
    /// exists, so a round that panicked mid-flight is replayed, not
    /// skipped.
    rounds_done: u64,
    /// The shard currently holding the live session.
    shard: usize,
}

impl SessionMeta {
    fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            cfg: self.cfg,
            d: self.d,
            seed: self.seed,
            qos: self.qos,
            rounds: self.rounds_done,
        }
    }
}

/// The session table plus frontend-wide counter folds. Deliberately
/// small: the router lock is on every request's path, so it guards only
/// O(1)/O(sessions) map bookkeeping, never engine work.
struct Router {
    sessions: BTreeMap<SessionId, SessionMeta>,
    /// Fold of closed sessions' admission counters, so frontend-wide
    /// stats survive tenant churn.
    closed_admission: AdmissionStats,
    /// Ditto for rounds run / dealt by closed sessions.
    closed_rounds_run: u64,
    closed_dealt: u64,
}

/// Service-level ceilings on wire-controlled sizes. The engine asserts
/// (panics) on shapes it was never built for and will happily allocate
/// whatever a caller asks — correct for in-process callers, fatal for a
/// server if the panic escaped to a shard lock. These are generous
/// bounds (orders of magnitude above the paper's operating points —
/// n ≤ 100, d ≈ 7.8k) that stop abuse without constraining use.
const MAX_USERS: usize = 4096;
const MAX_DIM: usize = 1 << 22;
const MAX_PREFETCH_ROUNDS: usize = 4096;

/// Reject wire shapes the engine cannot serve *before* they reach its
/// asserting surface (the contract is typed rejections for malformed
/// content, panics only for internal bugs — and even an internal panic
/// now costs one shard, not the server).
fn validate_shape(cfg: &HiSafeConfig, d: usize) -> Result<(), AdmissionError> {
    let bad = |reason: String| Err(AdmissionError::Rejected { reason });
    if cfg.n == 0 || cfg.ell == 0 {
        return bad(format!("n = {} and ell = {} must both be >= 1", cfg.n, cfg.ell));
    }
    if cfg.n % cfg.ell != 0 {
        return bad(format!("ell = {} must divide n = {}", cfg.ell, cfg.n));
    }
    if cfg.n > MAX_USERS {
        return bad(format!("n = {} exceeds the service cap of {MAX_USERS} users", cfg.n));
    }
    if d == 0 || d > MAX_DIM {
        return bad(format!("d = {d} must be in [1, {MAX_DIM}]"));
    }
    if let Err(e) = crate::quant::check_precision(cfg.precision) {
        return bad(e);
    }
    Ok(())
}

/// The typed-denial wire form of an [`Error`], echoing the session id
/// the request targeted (when it targeted one).
fn error_reply(session: Option<SessionId>, e: Error) -> Response {
    Response::Admission(AdmissionReply::denied(session, e.into_admission()))
}

/// The sharded service front-end: `K` scheduler shards behind per-shard
/// locks, a session router, and the wire-protocol [`Request`] surface.
/// See the module docs for locking, placement, death, and drain
/// semantics.
///
/// ```
/// use hisafe::engine::QosPolicy;
/// use hisafe::poly::TiePolicy;
/// use hisafe::protocol::HiSafeConfig;
/// use hisafe::service::{AggFrontend, Request, Response};
///
/// let fe = AggFrontend::new(2, 1);
/// let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
/// let open = Request::SessionOpen { cfg, d: 4, seed: 7, qos: QosPolicy::unlimited(), codec: None };
/// let sid = match fe.handle(&open) {
///     Response::Admission(r) => r.session.expect("granted"),
///     other => panic!("unexpected reply: {other:?}"),
/// };
/// let signs = vec![vec![1i8, -1, 1, -1]; 6];
/// match fe.handle(&Request::RoundSubmit { session: sid, signs, present: None }) {
///     Response::Vote(v) => assert_eq!(v.global_vote, vec![1, -1, 1, -1]),
///     other => panic!("unexpected reply: {other:?}"),
/// }
/// ```
pub struct AggFrontend {
    shards: Vec<ShardSlot>,
    router: Mutex<Router>,
    next_session: AtomicU64,
}

impl AggFrontend {
    /// A frontend over `shards` scheduler shards, each spawning
    /// `threads_per_shard` span workers (plus its dealer thread) lazily
    /// on first placement. No per-shard tenant cap.
    pub fn new(shards: usize, threads_per_shard: usize) -> AggFrontend {
        Self::build(shards, threads_per_shard, None)
    }

    /// Like [`new`](AggFrontend::new), but every shard refuses more than
    /// `max_tenants_per_shard` concurrent sessions — the placement layer
    /// then spills to the least-loaded shard, and `SessionOpen` is
    /// `Rejected` only when the whole frontend is full.
    pub fn with_shard_capacity(
        shards: usize,
        threads_per_shard: usize,
        max_tenants_per_shard: usize,
    ) -> AggFrontend {
        Self::build(shards, threads_per_shard, Some(max_tenants_per_shard))
    }

    fn build(shards: usize, threads: usize, max_tenants: Option<usize>) -> AggFrontend {
        assert!(shards >= 1, "a frontend needs at least one shard");
        assert!(threads >= 1, "shards need at least one worker thread");
        AggFrontend {
            shards: (0..shards)
                .map(|_| ShardSlot {
                    state: Mutex::new(ShardState {
                        sched: None,
                        threads,
                        max_tenants,
                        sessions: BTreeMap::new(),
                    }),
                    draining: AtomicBool::new(false),
                    dead: AtomicBool::new(false),
                    tenants: AtomicUsize::new(0),
                })
                .collect(),
            router: Mutex::new(Router {
                sessions: BTreeMap::new(),
                closed_admission: AdmissionStats::default(),
                closed_rounds_run: 0,
                closed_dealt: 0,
            }),
            next_session: AtomicU64::new(0),
        }
    }

    // ------------------------------------------------------------- locks

    /// Lock the router. The router mutex is never held across an engine
    /// call that could panic on wire input (only map bookkeeping), so a
    /// poisoned router means a frontend bug — recover the data anyway
    /// rather than bricking every session over a bookkeeping panic.
    fn lock_router(&self) -> MutexGuard<'_, Router> {
        self.router.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Lock shard `i`'s state, absorbing poison: if a previous holder
    /// panicked mid-round, the shard is marked dead exactly once and its
    /// state discarded (a panicked round may have consumed a partial
    /// round of triples, so the in-memory sessions are no longer
    /// trustworthy — their tenants restore from router metadata on next
    /// touch). Callers must re-check the `dead` flag after locking.
    fn lock_shard(&self, i: usize) -> MutexGuard<'_, ShardState> {
        match self.shards[i].state.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                let mut g = poisoned.into_inner();
                if !self.shards[i].dead.swap(true, Ordering::SeqCst) {
                    g.sessions.clear();
                    g.sched = None;
                    self.shards[i].tenants.store(0, Ordering::SeqCst);
                }
                g
            }
        }
    }

    fn shard_accepting(&self, i: usize) -> bool {
        !self.shards[i].dead.load(Ordering::SeqCst)
            && !self.shards[i].draining.load(Ordering::SeqCst)
    }

    // ---------------------------------------------------- introspection

    /// Number of scheduler shards (fixed at construction).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Live sessions per shard (frontend-side placement counts). Dead
    /// shards report 0 — their displaced sessions count nowhere until
    /// restored onto a live shard.
    pub fn shard_tenants(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.tenants.load(Ordering::SeqCst)).collect()
    }

    /// Total live sessions across every shard (including displaced
    /// sessions awaiting transparent restore).
    pub fn live_sessions(&self) -> usize {
        self.lock_router().sessions.len()
    }

    /// Whether shard `i` currently holds live scheduler infrastructure
    /// (a worker pool + dealing plane). False until first placement,
    /// after a drain empties it, and after death.
    pub fn shard_is_live(&self, i: usize) -> bool {
        self.lock_shard(i).sched.is_some()
    }

    /// Whether shard `i` has been marked dead (absorbed lock poison, or
    /// [`kill_shard`](AggFrontend::kill_shard)).
    pub fn shard_is_dead(&self, i: usize) -> bool {
        self.shards[i].dead.load(Ordering::SeqCst)
    }

    // --------------------------------------------------- drain / death

    /// Stop placing new tenants on shard `i`; its keys spill to their
    /// next-ranked shard exactly as if the shard were removed. Existing
    /// sessions keep running; the shard's scheduler (pool + plane) is
    /// retired as soon as its last session closes.
    ///
    /// # Panics
    ///
    /// If `i` is out of range, or if draining `i` would leave no shard
    /// accepting placements.
    pub fn drain_shard(&self, i: usize) {
        assert!(i < self.shards.len(), "shard {i} out of range");
        assert!(
            (0..self.shards.len()).any(|k| k != i && self.shard_accepting(k)),
            "cannot drain the last accepting shard"
        );
        self.shards[i].draining.store(true, Ordering::SeqCst);
        self.retire_if_drained(i);
    }

    /// Return a drained shard to the placement rotation (its scheduler
    /// respawns lazily on the next placement). Dead shards stay dead.
    ///
    /// # Panics
    ///
    /// If `i` is out of range.
    pub fn undrain_shard(&self, i: usize) {
        assert!(i < self.shards.len(), "shard {i} out of range");
        self.shards[i].draining.store(false, Ordering::SeqCst);
    }

    /// Kill shard `i` as if a panic had poisoned its lock: the shard is
    /// marked dead, its scheduler (pool + plane) torn down, and every
    /// session placed on it transparently restores onto the next-ranked
    /// live shard — bit-identically — on its next request. The
    /// operational/test hook for the failure path the poison-absorption
    /// machinery handles organically.
    ///
    /// # Panics
    ///
    /// If `i` is out of range.
    pub fn kill_shard(&self, i: usize) {
        assert!(i < self.shards.len(), "shard {i} out of range");
        let mut st = self.lock_shard(i);
        if !self.shards[i].dead.swap(true, Ordering::SeqCst) {
            st.sessions.clear();
            st.sched = None;
            self.shards[i].tenants.store(0, Ordering::SeqCst);
        }
    }

    /// The rebalance step: a draining shard with no tenants left drops
    /// its scheduler handle, tearing down its threads. (Sessions hold
    /// the scheduler core alive through their own `Arc`s, so this is
    /// safe even mid-race with a closing session.)
    fn retire_if_drained(&self, i: usize) {
        let mut st = self.lock_shard(i);
        if self.shards[i].draining.load(Ordering::SeqCst) && st.sessions.is_empty() {
            st.sched = None;
        }
    }

    // ------------------------------------------------------- placement

    /// Candidate shards for a tenant key, best first: the rendezvous
    /// winner, then the remaining accepting shards in least-loaded
    /// order (stable sort preserves rendezvous order among
    /// equally-loaded shards). Resolved entirely from atomics — no lock
    /// is held while ranking.
    fn placement_order(&self, cfg: &HiSafeConfig, d: usize, seed: u64) -> Vec<usize> {
        let rank = rendezvous_rank(tenant_key(cfg, d, seed), self.shards.len());
        let mut candidates: Vec<usize> =
            rank.into_iter().filter(|&i| self.shard_accepting(i)).collect();
        if candidates.len() > 1 {
            let mut spill = candidates.split_off(1);
            spill.sort_by_key(|&i| self.shards[i].tenants.load(Ordering::SeqCst));
            candidates.extend(spill);
        }
        candidates
    }

    /// Place a tenant (fresh at `resume_rounds = 0`, or resuming a
    /// snapshot): rendezvous winner first, then least-loaded spill-over.
    /// Locks one shard at a time; the router is touched only after the
    /// session exists (a brand-new id is unreachable by other threads
    /// until this returns it).
    fn place(
        &self,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
        qos: QosPolicy,
        resume_rounds: u64,
    ) -> Result<SessionId, Error> {
        // Validate shape and policy up front: both must be the same
        // typed rejection on every shard (and must never reach the
        // engine's asserting surface), so don't let either consume a
        // placement attempt (the shard re-validates the policy anyway).
        validate_shape(&cfg, d)?;
        qos.validate()?;
        let candidates = self.placement_order(&cfg, d, seed);
        if candidates.is_empty() {
            return Err(Error::Admission(AdmissionError::Rejected {
                reason: "every shard is draining or dead".into(),
            }));
        }
        let snap = SessionSnapshot { cfg, d, seed, qos, rounds: resume_rounds };
        let mut last_err = None;
        for i in candidates {
            let mut st = self.lock_shard(i);
            if self.shards[i].dead.load(Ordering::SeqCst) {
                continue;
            }
            // `try_session_resumed` at rounds = 0 is exactly
            // `try_session`, so fresh opens and restores share one path.
            match st.sched().try_session_resumed(&snap) {
                Ok(session) => {
                    let sid =
                        SessionId::new(self.next_session.fetch_add(1, Ordering::Relaxed));
                    st.sessions.insert(sid, session);
                    self.shards[i].tenants.fetch_add(1, Ordering::SeqCst);
                    drop(st);
                    self.lock_router().sessions.insert(
                        sid,
                        SessionMeta { cfg, d, seed, qos, rounds_done: resume_rounds, shard: i },
                    );
                    return Ok(sid);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(Error::Admission(last_err.unwrap_or(AdmissionError::Rejected {
            reason: "every shard is draining or dead".into(),
        })))
    }

    /// Re-place a session whose shard died, resuming it bit-identically
    /// from router metadata on the next-ranked live shard. Holds the
    /// router lock for the whole restore so concurrent restores of the
    /// same session serialize (the second one sees the updated placement
    /// and returns without doing anything).
    fn restore_displaced(&self, sid: SessionId) -> Result<(), Error> {
        let mut router = self.lock_router();
        let meta = match router.sessions.get(&sid) {
            Some(m) => m.clone(),
            None => return Err(Error::UnknownSession(sid)),
        };
        if !self.shards[meta.shard].dead.load(Ordering::SeqCst) {
            return Ok(()); // another thread already re-placed it
        }
        let snap = meta.snapshot();
        let candidates = self.placement_order(&meta.cfg, meta.d, meta.seed);
        let mut last_err = AdmissionError::Rejected {
            reason: format!("no live shard left to restore session {sid} onto"),
        };
        for i in candidates {
            let mut st = self.lock_shard(i);
            if self.shards[i].dead.load(Ordering::SeqCst) {
                continue;
            }
            match st.sched().try_session_resumed(&snap) {
                Ok(session) => {
                    st.sessions.insert(sid, session);
                    self.shards[i].tenants.fetch_add(1, Ordering::SeqCst);
                    drop(st);
                    router
                        .sessions
                        .get_mut(&sid)
                        .expect("meta pinned under the held router lock")
                        .shard = i;
                    return Ok(());
                }
                Err(e) => last_err = e,
            }
        }
        Err(Error::Admission(last_err))
    }

    /// Run `f` on the live [`AggSession`] for `sid`, restoring it first
    /// if its shard died. Returns the shard the call ran on. Retries a
    /// few times because the placement can move between the router
    /// lookup and the shard lock (a concurrent restore); every retry
    /// re-reads the router.
    fn with_session<T>(
        &self,
        sid: SessionId,
        mut f: impl FnMut(&mut AggSession) -> T,
    ) -> Result<(usize, T), Error> {
        for _ in 0..(self.shards.len() + 2) {
            let shard = match self.lock_router().sessions.get(&sid) {
                Some(m) => m.shard,
                None => return Err(Error::UnknownSession(sid)),
            };
            {
                let mut st = self.lock_shard(shard);
                if !self.shards[shard].dead.load(Ordering::SeqCst) {
                    if let Some(session) = st.sessions.get_mut(&sid) {
                        return Ok((shard, f(session)));
                    }
                    // Placement moved under us — re-read the router.
                    continue;
                }
            }
            // The shard is dead: resume the session from metadata, then
            // loop to run on the new placement.
            self.restore_displaced(sid)?;
        }
        Err(Error::Unexpected(format!(
            "session {sid} kept moving across {} routing attempts",
            self.shards.len() + 2
        )))
    }

    // -------------------------------------------------------- requests

    /// Answer one wire-protocol request. Never panics on malformed
    /// *content* (unknown sessions, wrong sign-matrix shapes, invalid
    /// policies all come back as typed [`AdmissionReply`] denials) —
    /// panicking is reserved for frontend-internal invariant breaks,
    /// and even those cost one shard (absorbed poison + transparent
    /// restore), never the frontend.
    pub fn handle(&self, req: &Request) -> Response {
        match req {
            // `codec` is transport negotiation, answered by the TCP
            // pump (`super::server`); the frontend routes sessions and
            // ignores it — in-process embedders have no wire to switch.
            Request::SessionOpen { cfg, d, seed, qos, codec: _ } => {
                match self.place(*cfg, *d, *seed, *qos, 0) {
                    Ok(sid) => Response::Admission(AdmissionReply::ok(Some(sid))),
                    Err(e) => error_reply(None, e),
                }
            }
            Request::SessionRestore { snapshot, codec: _ } => {
                match self.place(
                    snapshot.cfg,
                    snapshot.d,
                    snapshot.seed,
                    snapshot.qos,
                    snapshot.rounds,
                ) {
                    Ok(sid) => Response::Admission(AdmissionReply::ok(Some(sid))),
                    Err(e) => error_reply(None, e),
                }
            }
            Request::RoundSubmit { session, signs, present } => {
                // Shape-check against router metadata before the engine
                // surface: the engine asserts (panics) on bad shapes,
                // which is right for in-process bugs but must be a typed
                // rejection for wire input. The sign matrix keeps its
                // full n-row shape even under churn; the mask (when
                // carried at all) must name every registered user.
                let (n, d, precision) = match self.lock_router().sessions.get(session) {
                    Some(m) => (m.cfg.n, m.d, m.cfg.precision),
                    None => {
                        return error_reply(Some(*session), Error::UnknownSession(*session))
                    }
                };
                if signs.len() != n || signs.iter().any(|s| s.len() != d) {
                    return error_reply(
                        Some(*session),
                        Error::Admission(AdmissionError::Rejected {
                            reason: format!("sign matrix must be {n} users x {d} coordinates"),
                        }),
                    );
                }
                // Value-range check against the session's precision: the
                // wire alphabet is self-describing up to |v| = 15, so a
                // q = 4 session could otherwise smuggle q = 16 levels
                // into a polynomial that cannot represent them.
                let max_level = (precision - 1) as i8;
                if signs.iter().flatten().any(|&v| v.abs() > max_level) {
                    return error_reply(
                        Some(*session),
                        Error::Admission(AdmissionError::Rejected {
                            reason: format!(
                                "vote values must be in [-{max_level}, {max_level}] \
                                 for a precision-{precision} session"
                            ),
                        }),
                    );
                }
                if let Some(mask) = present {
                    if mask.len() != n {
                        return error_reply(
                            Some(*session),
                            Error::Admission(AdmissionError::Rejected {
                                reason: format!(
                                    "participant mask must cover all {n} users, got {}",
                                    mask.len()
                                ),
                            }),
                        );
                    }
                }
                let run = |s: &mut AggSession| match present {
                    // Absent mask ⇒ all-present: exactly the v1 path.
                    None => s.try_run_round(signs),
                    Some(mask) => {
                        s.try_run_round_present(signs, &ParticipantSet::from_mask(mask.clone()))
                    }
                };
                match self.with_session(*session, run) {
                    Ok((_, Ok(out))) => {
                        // Count the consumed round in the restore
                        // metadata only once the vote exists — a round
                        // that dies mid-flight is replayed, not skipped.
                        if let Some(m) = self.lock_router().sessions.get_mut(session) {
                            m.rounds_done += 1;
                        }
                        Response::Vote(VoteReply {
                            session: *session,
                            global_vote: out.global_vote,
                            subgroup_votes: out.subgroup_votes,
                            stats: out.stats,
                        })
                    }
                    Ok((_, Err(e))) => error_reply(Some(*session), Error::Admission(e)),
                    Err(e) => error_reply(Some(*session), e),
                }
            }
            Request::Prefetch { session, rounds } => {
                // Bound per-call dealing work: with an unbounded queue
                // depth (the tenant's own choice), a single wire request
                // could otherwise queue effectively infinite dealing.
                if *rounds > MAX_PREFETCH_ROUNDS {
                    return error_reply(
                        Some(*session),
                        Error::Admission(AdmissionError::Rejected {
                            reason: format!(
                                "prefetch of {rounds} rounds exceeds the service cap of \
                                 {MAX_PREFETCH_ROUNDS} per call"
                            ),
                        }),
                    );
                }
                match self.with_session(*session, |s| s.try_prefetch(*rounds)) {
                    Ok((_, Ok(()))) => Response::Admission(AdmissionReply::ok(Some(*session))),
                    Ok((_, Err(e))) => error_reply(Some(*session), Error::Admission(e)),
                    Err(e) => error_reply(Some(*session), e),
                }
            }
            Request::SessionClose { session } => self.close_session(*session),
            Request::StatsQuery { session: Some(sid) } => {
                match self.with_session(*sid, |s| {
                    (s.rounds_run(), s.dealt_rounds(), s.admission_stats())
                }) {
                    Ok((shard, (rounds_run, dealt_rounds, admission))) => {
                        Response::Stats(StatsReply {
                            session: Some(*sid),
                            shard: Some(shard),
                            rounds_run,
                            dealt_rounds,
                            admission,
                            shard_tenants: None,
                        })
                    }
                    Err(e) => error_reply(Some(*sid), e),
                }
            }
            Request::StatsQuery { session: None } => self.frontend_stats(),
            Request::SessionSnapshot { session } => {
                match self.lock_router().sessions.get(session) {
                    Some(m) => Response::Snapshot(SnapshotReply {
                        session: *session,
                        snapshot: m.snapshot(),
                    }),
                    None => error_reply(Some(*session), Error::UnknownSession(*session)),
                }
            }
            Request::SessionList => {
                let router = self.lock_router();
                Response::Sessions(SessionListReply {
                    sessions: router
                        .sessions
                        .iter()
                        .map(|(sid, m)| SnapshotReply { session: *sid, snapshot: m.snapshot() })
                        .collect(),
                })
            }
            Request::SessionDiscard { session } => self.discard_session(*session),
            // The frontend just acks; stopping the accept loop is the
            // transport layer's job (see `service::server`).
            Request::Shutdown => Response::Admission(AdmissionReply::ok(None)),
        }
    }

    fn close_session(&self, sid: SessionId) -> Response {
        let meta = match self.lock_router().sessions.remove(&sid) {
            Some(m) => m,
            None => return error_reply(Some(sid), Error::UnknownSession(sid)),
        };
        let removed = {
            let mut st = self.lock_shard(meta.shard);
            let r = st.sessions.remove(&sid);
            if r.is_some() {
                // Decrementing while the state lock is held is what
                // keeps this ordered against death-zeroing.
                self.shards[meta.shard].tenants.fetch_sub(1, Ordering::SeqCst);
            }
            r
        };
        {
            let mut router = self.lock_router();
            match &removed {
                Some(session) => {
                    router.closed_admission.merge(&session.admission_stats());
                    router.closed_rounds_run += session.rounds_run();
                    router.closed_dealt += session.dealt_rounds();
                }
                None => {
                    // The shard died and the session was never touched
                    // again: its engine-side counters went down with the
                    // shard, but the router knows the rounds it consumed
                    // (fold that count as the lower bound for dealing).
                    let synth = AdmissionStats {
                        admitted_rounds: meta.rounds_done,
                        ..AdmissionStats::default()
                    };
                    router.closed_admission.merge(&synth);
                    router.closed_rounds_run += meta.rounds_done;
                    router.closed_dealt += meta.rounds_done;
                }
            }
        }
        drop(removed); // deregisters from the shard's plane
        self.retire_if_drained(meta.shard);
        Response::Admission(AdmissionReply::ok(Some(sid)))
    }

    /// Remove a session *without* folding its counters into the
    /// frontend-wide closed aggregates. `SessionClose` folds because the
    /// session's history belongs to this frontend; a discarded session is
    /// a stale copy whose history is owned by its restored twin elsewhere
    /// in the cluster — folding it here would double-count those rounds
    /// in merged `cluster_stats`.
    fn discard_session(&self, sid: SessionId) -> Response {
        let meta = match self.lock_router().sessions.remove(&sid) {
            Some(m) => m,
            None => return error_reply(Some(sid), Error::UnknownSession(sid)),
        };
        let removed = {
            let mut st = self.lock_shard(meta.shard);
            let r = st.sessions.remove(&sid);
            if r.is_some() {
                self.shards[meta.shard].tenants.fetch_sub(1, Ordering::SeqCst);
            }
            r
        };
        drop(removed); // deregisters from the shard's plane
        self.retire_if_drained(meta.shard);
        Response::Admission(AdmissionReply::ok(Some(sid)))
    }

    fn frontend_stats(&self) -> Response {
        // Fold closed counters first (router lock alone), then walk the
        // shards one at a time — never two locks at once on this path.
        let (mut admission, mut rounds_run, mut dealt_rounds) = {
            let router = self.lock_router();
            (router.closed_admission.clone(), router.closed_rounds_run, router.closed_dealt)
        };
        for i in 0..self.shards.len() {
            let st = self.lock_shard(i);
            if self.shards[i].dead.load(Ordering::SeqCst) {
                continue;
            }
            for session in st.sessions.values() {
                admission.merge(&session.admission_stats());
                rounds_run += session.rounds_run();
                dealt_rounds += session.dealt_rounds();
            }
        }
        Response::Stats(StatsReply {
            session: None,
            shard: None,
            rounds_run,
            dealt_rounds,
            admission,
            shard_tenants: Some(self.shard_tenants()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::TiePolicy;
    use crate::protocol::{plain_hierarchical_vote, plain_hierarchical_vote_present};
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn open(fe: &AggFrontend, cfg: HiSafeConfig, d: usize, seed: u64) -> SessionId {
        let open = Request::SessionOpen { cfg, d, seed, qos: QosPolicy::unlimited(), codec: None };
        match fe.handle(&open) {
            Response::Admission(AdmissionReply { session: Some(sid), error: None, .. }) => sid,
            other => panic!("expected a session grant, got {other:?}"),
        }
    }

    fn shard_of(fe: &AggFrontend, sid: SessionId) -> usize {
        match fe.handle(&Request::StatsQuery { session: Some(sid) }) {
            Response::Stats(s) => s.shard.expect("session stats carry a shard"),
            other => panic!("expected stats, got {other:?}"),
        }
    }

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    /// 2k synthetic tenant keys for the placement-distribution tests
    /// (enough that a ±20% balance bound sits ≥ 4.5σ from the binomial
    /// mean — the fixed seed makes the test deterministic, the margin
    /// makes the fixed draw virtually certain to be a typical one).
    fn synthetic_keys() -> Vec<u64> {
        let mut rng = Xoshiro256pp::seed_from_u64(0x5a4d);
        (0..2000)
            .map(|i| {
                let cfg = HiSafeConfig::hierarchical(
                    6 * (1 + (i % 4)),
                    1 + (i % 4),
                    if i % 2 == 0 { TiePolicy::OneBit } else { TiePolicy::TwoBit },
                );
                tenant_key(&cfg, 64 + i, rng.next_u64())
            })
            .collect()
    }

    #[test]
    fn rendezvous_rank_is_deterministic_and_a_permutation() {
        for key in [0u64, 1, 0xdead_beef, u64::MAX] {
            for shards in [1usize, 2, 7, 16] {
                let a = rendezvous_rank(key, shards);
                let b = rendezvous_rank(key, shards);
                assert_eq!(a, b, "same key must rank identically");
                let mut sorted = a.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..shards).collect::<Vec<_>>(), "must be a permutation");
            }
        }
    }

    #[test]
    fn rendezvous_balances_synthetic_tenants_within_20pct() {
        let keys = synthetic_keys();
        for shards in [4usize, 5] {
            let mut counts = vec![0usize; shards];
            for &key in &keys {
                counts[rendezvous_rank(key, shards)[0]] += 1;
            }
            let expect = keys.len() / shards;
            for (i, &c) in counts.iter().enumerate() {
                assert!(
                    (c as f64) >= expect as f64 * 0.8 && (c as f64) <= expect as f64 * 1.2,
                    "shard {i}/{shards} got {c} of {} tenants (expected {expect} +/- 20%)",
                    keys.len()
                );
            }
        }
    }

    #[test]
    fn rendezvous_is_stable_under_shard_count_change() {
        // Growing K -> K+1 must move only the ~1/(K+1) of keys whose
        // winner is the NEW shard — and every moved key must move to it.
        let keys = synthetic_keys();
        for k in [4usize, 8] {
            let mut moved = 0usize;
            for &key in &keys {
                let before = rendezvous_rank(key, k)[0];
                let after = rendezvous_rank(key, k + 1)[0];
                if before != after {
                    moved += 1;
                    assert_eq!(
                        after, k,
                        "key {key:#x}: grew {k}->{} but moved to old shard {after}",
                        k + 1
                    );
                }
            }
            let expect = keys.len() / (k + 1);
            assert!(
                moved <= expect * 2 && moved >= expect / 2,
                "K={k}: {moved} of {} keys moved (expected ~{expect})",
                keys.len()
            );
            // Shrinking is the same statement read backwards: keys on
            // surviving shards stay put. (Already implied, but state it.)
            for &key in keys.iter().take(50) {
                let big = rendezvous_rank(key, k + 1)[0];
                if big != k {
                    assert_eq!(rendezvous_rank(key, k)[0], big);
                }
            }
        }
    }

    #[test]
    fn frontend_votes_match_plain_reference_across_shards() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = AggFrontend::new(3, 1);
        let sids: Vec<SessionId> = (0..4).map(|i| open(&fe, cfg, 5, 100 + i)).collect();
        assert_eq!(fe.live_sessions(), 4);
        for r in 0..2u64 {
            for (i, &sid) in sids.iter().enumerate() {
                let signs = rand_signs(6, 5, 7 + r * 10 + i as u64);
                match fe.handle(&Request::RoundSubmit { session: sid, signs: signs.clone(), present: None }) {
                    Response::Vote(v) => {
                        assert_eq!(v.global_vote, plain_hierarchical_vote(&signs, cfg));
                        assert_eq!(v.session, sid);
                    }
                    other => panic!("expected a vote, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn malformed_session_shapes_are_rejected_not_panics() {
        // A wire SessionOpen with a config the engine would assert on
        // (ell = 0, ell not dividing n, n = 0) — or absurd sizes — must
        // be a typed rejection before any engine surface is reached.
        let fe = AggFrontend::new(2, 1);
        let ok = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        for (cfg, d) in [
            (HiSafeConfig { ell: 0, ..ok }, 4),                  // ell = 0
            (HiSafeConfig { n: 5, ell: 2, ..ok }, 4),            // ell does not divide n
            (HiSafeConfig { n: 0, ell: 1, ..ok }, 4),            // no users
            (HiSafeConfig { n: MAX_USERS + 1, ell: 1, ..ok }, 4), // over the user cap
            (ok, 0),                                             // d = 0
            (ok, MAX_DIM + 1),                                   // over the dim cap
        ] {
            match fe.handle(&Request::SessionOpen {
                cfg,
                d,
                seed: 1,
                qos: QosPolicy::unlimited(),
                codec: None,
            }) {
                Response::Admission(AdmissionReply {
                    error: Some(AdmissionError::Rejected { .. }),
                    ..
                }) => {}
                other => panic!("cfg={cfg:?} d={d} must be rejected, got {other:?}"),
            }
        }
        assert_eq!(fe.live_sessions(), 0);
        // Oversized prefetch requests are capped per call, not executed.
        let sid = open(&fe, ok, 5, 1);
        match fe.handle(&Request::Prefetch { session: sid, rounds: MAX_PREFETCH_ROUNDS + 1 }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("service cap"), "reason: {reason}"),
            other => panic!("expected a prefetch cap rejection, got {other:?}"),
        }
    }

    #[test]
    fn malformed_round_shapes_are_rejected_not_panics() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = AggFrontend::new(1, 1);
        let sid = open(&fe, cfg, 5, 1);
        // Wrong user count and wrong dimension both come back typed.
        for signs in [rand_signs(5, 5, 2), rand_signs(6, 4, 3)] {
            match fe.handle(&Request::RoundSubmit { session: sid, signs, present: None }) {
                Response::Admission(AdmissionReply {
                    error: Some(AdmissionError::Rejected { reason }),
                    ..
                }) => assert!(reason.contains("sign matrix"), "reason: {reason}"),
                other => panic!("expected a shape rejection, got {other:?}"),
            }
        }
        // Unknown sessions likewise.
        match fe.handle(&Request::RoundSubmit {
            session: SessionId::new(999),
            signs: rand_signs(6, 5, 4),
            present: None,
        }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("unknown session"), "reason: {reason}"),
            other => panic!("expected unknown-session, got {other:?}"),
        }
    }

    #[test]
    fn capacity_spill_over_prefers_least_loaded_then_rejects_when_full() {
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let fe = AggFrontend::with_shard_capacity(2, 1, 2);
        // 4 tenants fill both shards (2 each) regardless of rendezvous
        // preference, because capacity overflow spills.
        let _sids: Vec<SessionId> = (0..4).map(|i| open(&fe, cfg, 4, i)).collect();
        assert_eq!(fe.shard_tenants(), vec![2, 2]);
        // A 5th tenant has nowhere to go.
        match fe.handle(&Request::SessionOpen {
            cfg,
            d: 4,
            seed: 99,
            qos: QosPolicy::unlimited(),
            codec: None,
        }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { .. }),
                ..
            }) => {}
            other => panic!("expected rejection at full capacity, got {other:?}"),
        }
    }

    #[test]
    fn drain_empties_and_retires_a_shard_then_undrain_restores_it() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = AggFrontend::new(2, 1);
        // Open sessions until both shards hold at least one, remembering
        // every session's shard.
        let mut placed: Vec<(SessionId, usize)> = Vec::new();
        let mut seed = 0u64;
        while !(placed.iter().any(|&(_, s)| s == 0) && placed.iter().any(|&(_, s)| s == 1)) {
            let sid = open(&fe, cfg, 5, seed);
            placed.push((sid, shard_of(&fe, sid)));
            seed += 1;
            assert!(seed < 100, "rendezvous never covered both shards");
        }
        let drained = 0usize;
        fe.drain_shard(drained);
        assert!(fe.shard_is_live(drained), "live sessions keep the scheduler");
        // New tenants all land on the surviving shard.
        for s in 100..104u64 {
            let sid = open(&fe, cfg, 5, s);
            assert_eq!(shard_of(&fe, sid), 1);
        }
        // The draining shard's sessions still run rounds.
        let on_drained: Vec<SessionId> =
            placed.iter().filter(|&&(_, s)| s == drained).map(|&(sid, _)| sid).collect();
        let signs = rand_signs(6, 5, 77);
        match fe.handle(&Request::RoundSubmit { session: on_drained[0], signs: signs.clone(), present: None }) {
            Response::Vote(v) => {
                assert_eq!(v.global_vote, plain_hierarchical_vote(&signs, cfg))
            }
            other => panic!("expected a vote, got {other:?}"),
        }
        // Closing its last session retires the shard's scheduler
        // (threads torn down); until then it stays live.
        for &sid in &on_drained {
            assert!(fe.shard_is_live(drained), "retire must wait for the last session");
            match fe.handle(&Request::SessionClose { session: sid }) {
                Response::Admission(AdmissionReply { error: None, .. }) => {}
                other => panic!("expected a close ack, got {other:?}"),
            }
        }
        assert!(!fe.shard_is_live(drained), "drained+empty shard must retire");
        // Undrain returns it to rotation; infrastructure respawns lazily.
        fe.undrain_shard(drained);
        let mut seed = 1000u64;
        loop {
            let sid = open(&fe, cfg, 5, seed);
            if shard_of(&fe, sid) == drained {
                break;
            }
            seed += 1;
            assert!(seed < 1100, "rendezvous never picked the undrained shard");
        }
        assert!(fe.shard_is_live(drained));
    }

    #[test]
    fn frontend_stats_merge_across_shards_and_survive_churn() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = AggFrontend::new(2, 1);
        let a = open(&fe, cfg, 5, 1);
        let b = open(&fe, cfg, 5, 2);
        for r in 0..3u64 {
            for &sid in [a, b].iter() {
                let signs = rand_signs(6, 5, 50 + r);
                match fe.handle(&Request::RoundSubmit { session: sid, signs, present: None }) {
                    Response::Vote(_) => {}
                    other => panic!("expected a vote, got {other:?}"),
                }
            }
        }
        // Close one session: its counters must fold into the aggregate.
        fe.handle(&Request::SessionClose { session: a });
        match fe.handle(&Request::StatsQuery { session: None }) {
            Response::Stats(s) => {
                assert_eq!(s.session, None);
                assert_eq!(s.rounds_run, 6, "3 rounds from each of 2 sessions");
                assert_eq!(s.admission.admitted_rounds, 6);
                let tenants = s.shard_tenants.expect("frontend scope lists shards");
                assert_eq!(tenants.len(), 2);
                assert_eq!(tenants.iter().sum::<usize>(), 1, "one session still live");
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn killed_shard_sessions_restore_transparently_with_bit_identical_votes() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let (d, rounds) = (9, 5);
        // Uninterrupted reference: same tenant on a 1-shard frontend.
        let reference = AggFrontend::new(1, 1);
        let ref_sid = open(&reference, cfg, d, 7);
        // Interrupted run: the tenant's shard is killed mid-sweep.
        let fe = AggFrontend::new(2, 1);
        let sid = open(&fe, cfg, d, 7);
        let before = shard_of(&fe, sid);
        for r in 0..rounds as u64 {
            let signs = rand_signs(cfg.n, d, 900 + r);
            if r == 2 {
                fe.kill_shard(before);
                assert!(fe.shard_is_dead(before));
            }
            let interrupted = match fe
                .handle(&Request::RoundSubmit { session: sid, signs: signs.clone(), present: None })
            {
                Response::Vote(v) => v,
                other => panic!("round {r} after kill must still vote, got {other:?}"),
            };
            let uninterrupted = match reference
                .handle(&Request::RoundSubmit { session: ref_sid, signs: signs.clone(), present: None })
            {
                Response::Vote(v) => v,
                other => panic!("reference round {r} failed: {other:?}"),
            };
            // Bit-identical across the kill: global AND subgroup votes.
            assert_eq!(interrupted.global_vote, uninterrupted.global_vote, "round {r}");
            assert_eq!(interrupted.subgroup_votes, uninterrupted.subgroup_votes, "round {r}");
            assert_eq!(interrupted.global_vote, plain_hierarchical_vote(&signs, cfg));
        }
        // The session now lives on the surviving shard, with counter
        // continuity: rounds_run picks up where the snapshot left off.
        let after = shard_of(&fe, sid);
        assert_ne!(after, before, "session must have moved off the dead shard");
        match fe.handle(&Request::StatsQuery { session: Some(sid) }) {
            Response::Stats(s) => {
                assert_eq!(s.rounds_run, rounds as u64);
                assert_eq!(s.admission.admitted_rounds, rounds as u64);
            }
            other => panic!("expected stats, got {other:?}"),
        }
        // And the dead shard stays out of placement.
        for s in 0..8u64 {
            let extra = open(&fe, cfg, d, 2000 + s);
            assert_eq!(shard_of(&fe, extra), after);
        }
    }

    #[test]
    fn poisoned_shard_lock_degrades_to_restore_not_a_bricked_frontend() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = AggFrontend::new(2, 1);
        let sid = open(&fe, cfg, 5, 3);
        let signs = rand_signs(6, 5, 11);
        match fe.handle(&Request::RoundSubmit { session: sid, signs: signs.clone(), present: None }) {
            Response::Vote(_) => {}
            other => panic!("expected a vote, got {other:?}"),
        }
        // Poison the session's shard lock the way a buggy handler would:
        // panic while holding it.
        let shard = shard_of(&fe, sid);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _guard = fe.shards[shard].state.lock().unwrap();
            panic!("simulated handler bug");
        }));
        assert!(result.is_err(), "the simulated panic must propagate");
        // The next request absorbs the poison (shard marked dead) and
        // transparently restores the session — same votes, no panic, no
        // poisoned-mutex unwrap anywhere on the path.
        let signs2 = rand_signs(6, 5, 12);
        match fe.handle(&Request::RoundSubmit { session: sid, signs: signs2.clone(), present: None }) {
            Response::Vote(v) => {
                assert_eq!(v.global_vote, plain_hierarchical_vote(&signs2, cfg))
            }
            other => panic!("expected a vote after poison recovery, got {other:?}"),
        }
        assert!(fe.shard_is_dead(shard));
        assert_ne!(shard_of(&fe, sid), shard);
        // New sessions keep being admitted (on the surviving shard).
        let extra = open(&fe, cfg, 5, 77);
        assert_ne!(shard_of(&fe, extra), shard);
    }

    #[test]
    fn snapshot_and_restore_requests_round_trip_across_frontends() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe_a = AggFrontend::new(2, 1);
        let sid = open(&fe_a, cfg, 5, 21);
        for r in 0..2u64 {
            let signs = rand_signs(6, 5, 300 + r);
            match fe_a.handle(&Request::RoundSubmit { session: sid, signs, present: None }) {
                Response::Vote(_) => {}
                other => panic!("expected a vote, got {other:?}"),
            }
        }
        // Snapshot reflects exactly the rounds consumed so far.
        let snap = match fe_a.handle(&Request::SessionSnapshot { session: sid }) {
            Response::Snapshot(s) => {
                assert_eq!(s.session, sid);
                assert_eq!(s.snapshot.rounds, 2);
                assert_eq!(s.snapshot.seed, 21);
                s.snapshot
            }
            other => panic!("expected a snapshot, got {other:?}"),
        };
        // Restore on a DIFFERENT frontend (the cross-host handoff the
        // balancer performs); the next round there must match the next
        // round on the original bit-for-bit.
        let fe_b = AggFrontend::new(3, 1);
        let restore = Request::SessionRestore { snapshot: snap, codec: None };
        let restored = match fe_b.handle(&restore) {
            Response::Admission(AdmissionReply { session: Some(s), error: None, .. }) => s,
            other => panic!("expected a restore grant, got {other:?}"),
        };
        let signs = rand_signs(6, 5, 302);
        let v_a = match fe_a.handle(&Request::RoundSubmit { session: sid, signs: signs.clone(), present: None })
        {
            Response::Vote(v) => v,
            other => panic!("expected a vote, got {other:?}"),
        };
        let v_b = match fe_b
            .handle(&Request::RoundSubmit { session: restored, signs: signs.clone(), present: None })
        {
            Response::Vote(v) => v,
            other => panic!("expected a vote, got {other:?}"),
        };
        assert_eq!(v_a.global_vote, v_b.global_vote);
        assert_eq!(v_a.subgroup_votes, v_b.subgroup_votes);
        assert_eq!(v_a.global_vote, plain_hierarchical_vote(&signs, cfg));
        // Unknown sessions get the typed unknown-session denial.
        match fe_b.handle(&Request::SessionSnapshot { session: SessionId::new(555) }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("unknown session"), "reason: {reason}"),
            other => panic!("expected unknown-session, got {other:?}"),
        }
    }

    #[test]
    fn shards_serve_rounds_concurrently_under_shared_reference() {
        // Two sessions pinned to different shards, driven from two
        // threads through one &AggFrontend: both must make progress and
        // produce reference votes (the per-shard-lock contract — with
        // one global lock this still passes, but the kill/restore and
        // bench coverage pin the parallelism; this pins thread-safety).
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = std::sync::Arc::new(AggFrontend::new(2, 1));
        let mut sids = Vec::new();
        let mut seed = 0u64;
        while sids.len() < 2 {
            let sid = open(&fe, cfg, 5, seed);
            if sids.iter().all(|&(_, sh)| sh != shard_of(&fe, sid)) {
                sids.push((sid, shard_of(&fe, sid)));
            } else {
                fe.handle(&Request::SessionClose { session: sid });
            }
            seed += 1;
            assert!(seed < 100, "rendezvous never covered both shards");
        }
        let handles: Vec<_> = sids
            .iter()
            .map(|&(sid, _)| {
                let fe = fe.clone();
                std::thread::spawn(move || {
                    for r in 0..4u64 {
                        let signs = rand_signs(6, 5, sid.as_u64() * 100 + r);
                        match fe.handle(&Request::RoundSubmit {
                            session: sid,
                            signs: signs.clone(),
                            present: None,
                        }) {
                            Response::Vote(v) => assert_eq!(
                                v.global_vote,
                                plain_hierarchical_vote(&signs, cfg)
                            ),
                            other => panic!("expected a vote, got {other:?}"),
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker thread must not panic");
        }
    }

    #[test]
    fn churned_submits_vote_over_survivors_and_below_threshold_is_typed() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let fe = AggFrontend::new(2, 1);
        let sid = open(&fe, cfg, 5, 13);
        // Group 0 loses one of three members: survivors = 2 ≥ required
        // = 2, so the round completes — voting over the survivor set.
        let mask = vec![true, false, true, true, true, true];
        let signs = rand_signs(6, 5, 401);
        match fe.handle(&Request::RoundSubmit {
            session: sid,
            signs: signs.clone(),
            present: Some(mask.clone()),
        }) {
            Response::Vote(v) => {
                let set = ParticipantSet::from_mask(mask.clone());
                assert_eq!(v.global_vote, plain_hierarchical_vote_present(&signs, &set, cfg));
            }
            other => panic!("expected a survivor-set vote, got {other:?}"),
        }
        // Group 0 loses two of three: survivors = 1 < required = 2 —
        // a typed churn denial, not a panic, and the session survives.
        let starved = vec![true, false, false, true, true, true];
        match fe.handle(&Request::RoundSubmit {
            session: sid,
            signs: signs.clone(),
            present: Some(starved),
        }) {
            Response::Admission(AdmissionReply {
                error:
                    Some(AdmissionError::ChurnBelowThreshold { group: 0, survivors: 1, required: 2 }),
                ..
            }) => {}
            other => panic!("expected a churn denial, got {other:?}"),
        }
        // A mask that doesn't cover every registered user is a typed
        // shape rejection before any engine surface is reached.
        match fe.handle(&Request::RoundSubmit {
            session: sid,
            signs: signs.clone(),
            present: Some(vec![true; 5]),
        }) {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("participant mask"), "reason: {reason}"),
            other => panic!("expected a mask-shape rejection, got {other:?}"),
        }
        // And the session still runs all-present rounds afterwards.
        match fe.handle(&Request::RoundSubmit { session: sid, signs: signs.clone(), present: None })
        {
            Response::Vote(v) => {
                assert_eq!(v.global_vote, plain_hierarchical_vote(&signs, cfg))
            }
            other => panic!("expected a vote, got {other:?}"),
        }
    }
}
