//! Shamir secret sharing + DN07-style secure multiplication — the
//! alternative MPC backend the paper names ("other secure multiplication
//! techniques (e.g., DN [40] and ATLAS [41]) can be seamlessly
//! integrated", Section III-A).
//!
//! Scheme (honest majority, threshold `t < n/2`):
//! * a secret `z` is shared as evaluations of a random degree-`t`
//!   polynomial `f` with `f(0) = z` at points `1..=n`;
//! * addition is local; multiplication of two degree-`t` sharings yields a
//!   degree-`2t` sharing, which is *degree-reduced* via the
//!   Damgård–Nielsen king-node pattern: parties mask the product sharing
//!   with a pre-distributed double sharing `(⟨r⟩_t, ⟨r⟩_2t)`, open
//!   `x·y − r` (degree 2t, reconstructible by 2t+1 ≤ n parties), and the
//!   king broadcasts it; parties add it to `⟨r⟩_t`.
//!
//! Integration with Hi-SAFE: users Shamir-share their ±1 inputs, locally
//! sum the shares of all users (obtaining a sharing of `x = Σ xᵢ`), run
//! the same [`PowerSchedule`] as the Beaver path with DN multiplications,
//! combine with the polynomial coefficients, and open only `F(x)` — the
//! same leakage profile as Theorem 2. [`shamir_group_vote`] implements the
//! full pipeline; tests assert it equals the plaintext majority vote and
//! the Beaver-path outcome.

use crate::field::Fp;
use crate::poly::{MvPolynomial, PowerSchedule, TiePolicy};
use crate::util::rng::{ChaCha20Rng, Rng};

/// Share a secret as `f(1..=n)` for random degree-`t` poly with
/// `f(0) = secret`.
pub fn share<R: Rng>(fp: Fp, secret: u64, n: usize, t: usize, rng: &mut R) -> Vec<u64> {
    assert!(t < n, "threshold must be below party count");
    assert!((n as u64) < fp.modulus(), "need n distinct nonzero points");
    let p = fp.modulus();
    // coefficients: [secret, c1..ct]
    let mut coeffs = vec![secret];
    for _ in 0..t {
        coeffs.push(rng.gen_field(p));
    }
    (1..=n as u64)
        .map(|x| {
            // Horner
            let mut acc = 0u64;
            for &c in coeffs.iter().rev() {
                acc = fp.add(fp.mul(acc, x), c);
            }
            acc
        })
        .collect()
}

/// Lagrange-interpolate `f(0)` from shares at points `points` (1-based
/// party ids). Needs `deg(f) + 1` points.
pub fn reconstruct(fp: Fp, points: &[usize], shares: &[u64]) -> u64 {
    assert_eq!(points.len(), shares.len());
    let mut acc = 0u64;
    for (i, (&xi, &yi)) in points.iter().zip(shares).enumerate() {
        let xi = xi as u64;
        let mut num = 1u64;
        let mut den = 1u64;
        for (j, &xj) in points.iter().enumerate() {
            if i == j {
                continue;
            }
            let xj = xj as u64;
            num = fp.mul(num, fp.neg(fp.reduce(xj))); // (0 − xj)
            den = fp.mul(den, fp.sub(fp.reduce(xi), fp.reduce(xj)));
        }
        let lag = fp.mul(num, fp.inv(den));
        acc = fp.add(acc, fp.mul(yi, lag));
    }
    acc
}

/// A double sharing `(⟨r⟩_t, ⟨r⟩_2t)` of the same random `r` — the DN07
/// preprocessing object (one consumed per multiplication).
#[derive(Debug, Clone)]
pub struct DoubleShare {
    pub deg_t: Vec<u64>,
    pub deg_2t: Vec<u64>,
}

/// Trusted-dealer generation of double sharings (same substitution
/// rationale as the Beaver dealer — DESIGN.md §Substitutions).
pub struct DnDealer {
    fp: Fp,
    n: usize,
    t: usize,
    rng: ChaCha20Rng,
    pub generated: usize,
}

impl DnDealer {
    pub fn new(fp: Fp, n: usize, t: usize, seed: u64) -> DnDealer {
        assert!(2 * t < n, "DN needs honest majority: 2t < n");
        DnDealer { fp, n, t, rng: ChaCha20Rng::seed_from_u64(seed), generated: 0 }
    }

    pub fn gen_double(&mut self) -> DoubleShare {
        let r = self.rng.gen_field(self.fp.modulus());
        let deg_t = share(self.fp, r, self.n, self.t, &mut self.rng);
        let deg_2t = share(self.fp, r, self.n, 2 * self.t, &mut self.rng);
        self.generated += 1;
        DoubleShare { deg_t, deg_2t }
    }
}

/// One DN multiplication on vectors of shares (per-party views):
/// `x_shares[i]`, `y_shares[i]` are party `i`'s degree-`t` shares.
/// Returns the degree-`t` sharing of `x·y` plus the opened masked value
/// (the protocol's only public message — uniform, like Beaver's δ/ε).
pub fn dn_multiply(
    fp: Fp,
    t: usize,
    x_shares: &[u64],
    y_shares: &[u64],
    double: &DoubleShare,
) -> (Vec<u64>, u64) {
    let n = x_shares.len();
    assert!(2 * t < n);
    // local degree-2t product minus the 2t-sharing of r
    let masked: Vec<u64> = (0..n)
        .map(|i| fp.sub(fp.mul(x_shares[i], y_shares[i]), double.deg_2t[i]))
        .collect();
    // king reconstructs d = x·y − r from any 2t+1 shares
    let pts: Vec<usize> = (1..=2 * t + 1).collect();
    let d = reconstruct(fp, &pts, &masked[..2 * t + 1]);
    // parties: ⟨xy⟩_t = ⟨r⟩_t + d (constant added to the share of ONE
    // polynomial — constants add to every share since f(0)+d shifts f)
    let out: Vec<u64> = (0..n).map(|i| fp.add(double.deg_t[i], d)).collect();
    (out, d)
}

/// Full Hi-SAFE group vote over the DN/Shamir backend (threshold
/// `t = ⌊(n−1)/2⌋`): share inputs → sum locally → power schedule via DN
/// mults → combine coefficients → open `F(x)` only.
pub fn shamir_group_vote(signs: &[Vec<i8>], policy: TiePolicy, seed: u64) -> Vec<i8> {
    let n = signs.len();
    assert!(n >= 3, "DN needs n ≥ 3 (honest majority)");
    let d = signs[0].len();
    let t = (n - 1) / 2;
    let mv = MvPolynomial::build_fermat(n, policy);
    let fp = mv.fp;
    let sched = PowerSchedule::full(mv.degree());
    let mut dealer = DnDealer::new(fp, n, t, seed);
    let mut rng = ChaCha20Rng::seed_from_u64(seed ^ 0x5a5a);

    let mut votes = Vec::with_capacity(d);
    for j in 0..d {
        // input sharing round: each user Shamir-shares its sign
        let mut sum_shares = vec![0u64; n];
        for s in signs {
            let sh = share(fp, fp.from_i64(s[j] as i64), n, t, &mut rng);
            for i in 0..n {
                sum_shares[i] = fp.add(sum_shares[i], sh[i]);
            }
        }
        // powers via the same schedule as the Beaver path
        let max_pow = sched.max_power.max(1);
        let mut powers: Vec<Option<Vec<u64>>> = vec![None; max_pow + 1];
        powers[1] = Some(sum_shares);
        for step in &sched.steps {
            let left = powers[step.left].clone().expect("left power");
            let right = powers[step.right].clone().expect("right power");
            let dbl = dealer.gen_double();
            let (prod, _opened) = dn_multiply(fp, t, &left, &right, &dbl);
            powers[step.target] = Some(prod);
        }
        // combine: ⟨F(x)⟩ = Σ coeff_k·⟨x^k⟩ (+ c0)
        let mut fshare = vec![0u64; n];
        for (k, &c) in mv.poly.coeffs.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if k == 0 {
                for v in fshare.iter_mut() {
                    *v = fp.add(*v, c);
                }
                continue;
            }
            let pw = powers[k].as_ref().expect("power");
            for i in 0..n {
                fshare[i] = fp.add(fshare[i], fp.mul(c, pw[i]));
            }
        }
        // open F(x) from t+1 shares
        let pts: Vec<usize> = (1..=t + 1).collect();
        let fx = reconstruct(fp, &pts, &fshare[..t + 1]);
        votes.push(fp.sign_of(fx));
    }
    votes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::plain_group_vote;
    use crate::prop_assert_eq;
    use crate::util::prop::forall;

    #[test]
    fn share_reconstruct_roundtrip() {
        forall("shamir roundtrip", 200, |g| {
            let n = g.usize_range(3, 12);
            let p = crate::field::next_prime(g.range(n as u64, 97));
            let fp = Fp::new(p);
            let t = g.usize_range(1, ((n - 1) / 2).max(1));
            let secret = g.field(p);
            let mut rng = ChaCha20Rng::seed_from_u64(g.u64());
            let shares = share(fp, secret, n, t, &mut rng);
            // any t+1 shares reconstruct
            let pts: Vec<usize> = (1..=t + 1).collect();
            prop_assert_eq!(reconstruct(fp, &pts, &shares[..t + 1]), secret);
            // a different subset too (last t+1)
            let pts2: Vec<usize> = (n - t..=n).collect();
            prop_assert_eq!(reconstruct(fp, &pts2, &shares[n - t - 1..]), secret);
            Ok(())
        });
    }

    #[test]
    fn t_shares_leak_nothing_statistically() {
        // With t = 1, a single share must be uniform regardless of secret.
        let fp = Fp::new(11);
        let mut rng = ChaCha20Rng::seed_from_u64(5);
        let mut counts = [[0u64; 11]; 2];
        for trial in 0..22_000 {
            let secret = if trial % 2 == 0 { 3 } else { 9 };
            let sh = share(fp, secret, 5, 1, &mut rng);
            counts[trial % 2][sh[2] as usize] += 1;
        }
        let chi2 = crate::security::chi_square_two_sample(&counts[0], &counts[1]);
        assert!(chi2 < crate::security::chi2_threshold(10), "χ² = {chi2}");
    }

    #[test]
    fn dn_multiplication_correct() {
        forall("DN x·y", 120, |g| {
            let n = g.usize_range(3, 9);
            let p = crate::field::next_prime(g.range(n as u64, 97));
            let fp = Fp::new(p);
            let t = (n - 1) / 2;
            let (x, y) = (g.field(p), g.field(p));
            let mut rng = ChaCha20Rng::seed_from_u64(g.u64());
            let xs = share(fp, x, n, t, &mut rng);
            let ys = share(fp, y, n, t, &mut rng);
            let mut dealer = DnDealer::new(fp, n, t, g.u64() ^ 1);
            let dbl = dealer.gen_double();
            let (prod, _d) = dn_multiply(fp, t, &xs, &ys, &dbl);
            let pts: Vec<usize> = (1..=t + 1).collect();
            prop_assert_eq!(
                reconstruct(fp, &pts, &prod[..t + 1]),
                fp.mul(x, y),
                "n={n} t={t}"
            );
            Ok(())
        });
    }

    #[test]
    fn dn_opened_value_is_masked() {
        // the only public message is x·y − r with r uniform ⇒ uniform.
        let fp = Fp::new(11);
        let mut counts = vec![0u64; 11];
        for seed in 0..8_000u64 {
            let mut rng = ChaCha20Rng::seed_from_u64(seed);
            let xs = share(fp, 7, 5, 2, &mut rng);
            let ys = share(fp, 3, 5, 2, &mut rng);
            let mut dealer = DnDealer::new(fp, 5, 2, seed ^ 99);
            let dbl = dealer.gen_double();
            let (_, d) = dn_multiply(fp, 2, &xs, &ys, &dbl);
            counts[d as usize] += 1;
        }
        let chi2 = crate::security::chi_square_uniform(&counts);
        assert!(chi2 < crate::security::chi2_threshold(10), "χ² = {chi2}");
    }

    #[test]
    fn shamir_vote_equals_plain_vote() {
        forall("shamir backend ≡ plaintext MV", 25, |g| {
            let n = g.usize_range(3, 8);
            let d = g.usize_range(1, 6);
            let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            prop_assert_eq!(
                shamir_group_vote(&signs, policy, g.u64()),
                plain_group_vote(&signs, policy),
                "n={n} {policy:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn shamir_vote_equals_beaver_vote() {
        // the two backends are interchangeable — the paper's claim.
        let signs: Vec<Vec<i8>> = vec![
            vec![1, -1, 1, 1],
            vec![-1, -1, 1, -1],
            vec![1, 1, 1, -1],
            vec![1, -1, -1, -1],
            vec![-1, -1, 1, 1],
        ];
        let beaver = crate::mpc::secure_group_vote(&signs, TiePolicy::OneBit, false, 3);
        let shamir = shamir_group_vote(&signs, TiePolicy::OneBit, 3);
        assert_eq!(beaver.votes, shamir);
    }

    #[test]
    #[should_panic(expected = "honest majority")]
    fn dn_rejects_dishonest_majority() {
        let fp = Fp::new(7);
        let _ = DnDealer::new(fp, 4, 2, 0); // 2t = 4 = n — rejected
    }
}
