//! q-level quantized aggregation — the heterogeneous-precision
//! generalization of the 1-bit majority vote (HeteroSAg/ScionFL-style
//! multi-level quantization on Hi-SAFE's polynomial machinery).
//!
//! A precision-`q` tenant (`q ∈ {2, 4, 8, 16}`) votes with **midrise
//! levels** `L_q = {−(q−1), −(q−3), …, q−1}` — the `q` odd integers
//! centered on zero, step 2. For `q = 2` that is `{−1, +1}`: the sign
//! vote, byte for byte.
//!
//! The aggregate of `n` levels summing to `s` is the level nearest the
//! mean `s/n` ([`quant_aggregate`]); an exact midpoint (the mean lands
//! halfway between two adjacent levels) resolves by [`TiePolicy`]:
//! `OneBit` rounds to the **lower** level (matching the paper's
//! `sign(0) = −1` at `q = 2`), `TwoBit` outputs the even midpoint value
//! itself (matching `sign(0) = 0`). Because every input is odd, midpoints
//! only occur when `n | s` with an even quotient — exactly the `q = 2`
//! tie, generalized.
//!
//! The secure path interpolates this aggregate map over `F_p` with
//! `p = next_prime(max(n,2)·(q−1))`
//! ([`crate::poly::MvPolynomial::build_fermat_q`]); at `q = 2` the prime,
//! the polynomial, and therefore the Beaver schedule and every dealer
//! stream collapse to the legacy sign-vote construction — the equality is
//! pinned coefficient-for-coefficient by the poly tests.

use crate::poly::TiePolicy;

/// The supported precisions: powers of two so level indices pack into
/// whole bits on the wire.
pub const PRECISIONS: [u8; 4] = [2, 4, 8, 16];

/// Panic unless `q` is a supported precision.
pub fn validate_precision(q: u8) {
    assert!(
        PRECISIONS.contains(&q),
        "precision must be one of {PRECISIONS:?}, got {q}"
    );
}

/// `Ok` iff `q` is a supported precision — the non-panicking check the
/// service admission path uses.
pub fn check_precision(q: u8) -> Result<(), String> {
    if PRECISIONS.contains(&q) {
        Ok(())
    } else {
        Err(format!("precision must be one of {PRECISIONS:?}, got {q}"))
    }
}

/// The midrise level set `L_q = {−(q−1), −(q−3), …, q−1}` in ascending
/// order. `levels(2) == [−1, 1]` — the sign alphabet.
pub fn levels(q: u8) -> Vec<i64> {
    validate_precision(q);
    let qm1 = (q - 1) as i64;
    (-qm1..=qm1).step_by(2).collect()
}

/// The q-level aggregate `g(s)` of `n` inputs summing to `s`: the level
/// in `L_q` nearest `s/n`, with an exact midpoint resolved by `policy`
/// (`OneBit` → lower level, `TwoBit` → the even midpoint value). Means
/// beyond the extreme levels clamp. `quant_aggregate(s, n, 2, policy)`
/// is exactly `policy.sign(s)`.
pub fn quant_aggregate(sum: i64, n: usize, q: u8, policy: TiePolicy) -> i64 {
    assert!(n >= 1, "aggregate of at least one input");
    validate_precision(q);
    let qm1 = (q - 1) as i64;
    let n_i = n as i64;
    // Scan the ≤ 16 levels ascending; |s − n·ℓ| is V-shaped in ℓ, so an
    // equal distance can only be the two levels straddling s/n — the
    // midpoint tie.
    let mut best = -qm1;
    let mut best_dist = (sum + n_i * qm1).abs();
    let mut lvl = -qm1 + 2;
    while lvl <= qm1 {
        let dist = (sum - n_i * lvl).abs();
        if dist < best_dist {
            best = lvl;
            best_dist = dist;
        } else if dist == best_dist {
            // exact midpoint between `best` (= lvl − 2) and `lvl`
            return match policy {
                TiePolicy::OneBit => best,
                TiePolicy::TwoBit => lvl - 1,
            };
        }
        lvl += 2;
    }
    best
}

/// Downlink bits per coordinate for a precision-`q` vote. At `q = 2`
/// this is the legacy policy-driven 1/2-bit downlink; a `q > 2` vote can
/// take any of the `2q − 1` values in `[−(q−1), q−1]` (even values at
/// `TwoBit` midpoints), so it costs `⌈log₂(2q−1)⌉` bits regardless of
/// policy.
pub fn downlink_bits(q: u8, inter: TiePolicy) -> u32 {
    validate_precision(q);
    if q == 2 {
        inter.downlink_bits()
    } else {
        let symbols = 2 * q as u32 - 1;
        32 - (symbols - 1).leading_zeros()
    }
}

/// Uplink bits per coordinate a precision-`q` *input* costs on the wire:
/// `q` odd levels plus the absent/zero symbol pack into
/// `⌈log₂(q+1)⌉` bits. `uplink_bits(2) == 2` — the legacy 2-bit sign
/// packing.
pub fn uplink_bits(q: u8) -> u32 {
    validate_precision(q);
    let symbols = q as u32 + 1;
    32 - (symbols - 1).leading_zeros()
}

/// A per-tenant gradient quantizer onto `L_q`: `x ↦ level ≈ x / scale`.
///
/// Two rounding modes:
/// * [`Quantizer::quantize`] — deterministic midrise: the level whose
///   half-open cell `[2k, 2k+2)` contains `x/scale` (so at `q = 2` it is
///   the sign with `0 ↦ +1`).
/// * [`Quantizer::quantize_stochastic`] — unbiased stochastic rounding
///   between the two bracketing levels; the caller supplies the uniform
///   draw so every execution path stays a pure function of its streams.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    /// Number of levels (`∈ {2, 4, 8, 16}`).
    pub q: u8,
    /// Per-tenant scale: the gradient magnitude one level step represents.
    pub scale: f32,
}

impl Quantizer {
    pub fn new(q: u8, scale: f32) -> Quantizer {
        validate_precision(q);
        assert!(scale > 0.0 && scale.is_finite(), "scale must be positive, got {scale}");
        Quantizer { q, scale }
    }

    fn clamp(&self, lvl: i64) -> i8 {
        let qm1 = (self.q - 1) as i64;
        lvl.clamp(-qm1, qm1) as i8
    }

    /// Deterministic midrise quantization of one coordinate.
    pub fn quantize(&self, x: f32) -> i8 {
        let y = (x / self.scale) as f64;
        // the odd integer whose cell [2k, 2k+2) contains y
        self.clamp(2 * (y / 2.0).floor() as i64 + 1)
    }

    /// Unbiased stochastic rounding: round `x/scale` up to the next level
    /// with probability proportional to its position in the level cell.
    /// `u` is a uniform draw in `[0, 1)`.
    pub fn quantize_stochastic(&self, x: f32, u: f64) -> i8 {
        debug_assert!((0.0..1.0).contains(&u), "u must be a unit draw, got {u}");
        let qm1 = (self.q - 1) as f64;
        let y = ((x / self.scale) as f64).clamp(-qm1, qm1);
        // largest level ≤ y, and its upper neighbor
        let lo = 2.0 * ((y + 1.0) / 2.0).floor() - 1.0;
        let up = (y - lo) / 2.0; // ∈ [0, 1)
        self.clamp(if u < up { lo as i64 + 2 } else { lo as i64 })
    }

    /// Quantize a full vector deterministically.
    pub fn quantize_vec(&self, xs: &[f32]) -> Vec<i8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Map a level back to gradient space.
    pub fn dequantize(&self, level: i8) -> f32 {
        level as f32 * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_sets() {
        assert_eq!(levels(2), vec![-1, 1]);
        assert_eq!(levels(4), vec![-3, -1, 1, 3]);
        assert_eq!(levels(8), vec![-7, -5, -3, -1, 1, 3, 5, 7]);
        assert_eq!(levels(16).len(), 16);
        assert_eq!(levels(16)[0], -15);
        assert_eq!(*levels(16).last().unwrap(), 15);
    }

    #[test]
    #[should_panic(expected = "precision must be one of")]
    fn rejects_unsupported_precision() {
        validate_precision(3);
    }

    /// `q = 2` collapses to the legacy sign with the policy tie — the
    /// byte-for-byte anchor for the whole subsystem.
    #[test]
    fn q2_aggregate_is_the_policy_sign() {
        for n in 1..=12usize {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                for sum in -(n as i64)..=(n as i64) {
                    assert_eq!(
                        quant_aggregate(sum, n, 2, policy),
                        policy.sign(sum),
                        "n={n} sum={sum} {policy:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn aggregate_is_nearest_level() {
        // n = 2, q = 4: mean 2.0 is the midpoint of levels 1 and 3.
        assert_eq!(quant_aggregate(4, 2, 4, TiePolicy::OneBit), 1);
        assert_eq!(quant_aggregate(4, 2, 4, TiePolicy::TwoBit), 2);
        // mean 2.5 → nearest level 3 under both policies
        assert_eq!(quant_aggregate(5, 2, 4, TiePolicy::OneBit), 3);
        assert_eq!(quant_aggregate(5, 2, 4, TiePolicy::TwoBit), 3);
        // extreme sums clamp to the extreme level
        assert_eq!(quant_aggregate(21, 3, 8, TiePolicy::OneBit), 7);
        assert_eq!(quant_aggregate(-21, 3, 8, TiePolicy::OneBit), -7);
    }

    #[test]
    fn aggregate_is_odd_symmetric_off_ties() {
        // g(−s) = −g(s) whenever s is not a midpoint (OneBit breaks the
        // symmetry only at ties, exactly like sign at 0).
        for q in PRECISIONS {
            for n in 1..=6usize {
                let hi = n as i64 * (q as i64 - 1);
                for s in -hi..=hi {
                    let a = quant_aggregate(s, n, q, TiePolicy::TwoBit);
                    let b = quant_aggregate(-s, n, q, TiePolicy::TwoBit);
                    assert_eq!(a, -b, "q={q} n={n} s={s}");
                }
            }
        }
    }

    #[test]
    fn wire_bit_widths() {
        assert_eq!(uplink_bits(2), 2); // legacy 2-bit sign packing
        assert_eq!(uplink_bits(4), 3);
        assert_eq!(uplink_bits(8), 4);
        assert_eq!(uplink_bits(16), 5);
        assert_eq!(downlink_bits(2, TiePolicy::OneBit), 1);
        assert_eq!(downlink_bits(2, TiePolicy::TwoBit), 2);
        for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
            assert_eq!(downlink_bits(4, policy), 3);
            assert_eq!(downlink_bits(8, policy), 4);
            assert_eq!(downlink_bits(16, policy), 5);
        }
    }

    #[test]
    fn deterministic_quantizer_at_q2_is_the_sign() {
        let z = Quantizer::new(2, 1.0);
        assert_eq!(z.quantize(3.7), 1);
        assert_eq!(z.quantize(-0.001), -1);
        assert_eq!(z.quantize(0.0), 1); // midrise: 0 sits in the +1 cell
    }

    #[test]
    fn deterministic_quantizer_hits_every_level() {
        for q in PRECISIONS {
            let z = Quantizer::new(q, 0.5);
            for lvl in levels(q) {
                // the cell center lvl·scale maps back to lvl
                assert_eq!(z.quantize(lvl as f32 * 0.5), lvl as i8, "q={q} lvl={lvl}");
                assert_eq!(z.dequantize(lvl as i8), lvl as f32 * 0.5);
            }
            // clamping beyond the extremes
            assert_eq!(z.quantize(1e6), (q - 1) as i8);
            assert_eq!(z.quantize(-1e6), -((q - 1) as i8));
        }
    }

    #[test]
    fn stochastic_quantizer_is_unbiased_and_bracketing() {
        let z = Quantizer::new(8, 1.0);
        // y = 2.5 sits between levels 1 and 3, 75% of the way up
        assert_eq!(z.quantize_stochastic(2.5, 0.74), 3);
        assert_eq!(z.quantize_stochastic(2.5, 0.76), 1);
        // exactly on a level: never moves
        for u in [0.0, 0.3, 0.99] {
            assert_eq!(z.quantize_stochastic(3.0, u), 3);
        }
        // empirical mean over a deterministic low-discrepancy sweep
        let y = 1.8f32;
        let n = 10_000;
        let mean: f64 = (0..n)
            .map(|i| z.quantize_stochastic(y, (i as f64 + 0.5) / n as f64) as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean - y as f64).abs() < 1e-2, "mean {mean} vs {y}");
    }

    #[test]
    fn stochastic_clamps_out_of_range() {
        let z = Quantizer::new(4, 1.0);
        for u in [0.0, 0.5, 0.999] {
            assert_eq!(z.quantize_stochastic(100.0, u), 3);
            assert_eq!(z.quantize_stochastic(-100.0, u), -3);
        }
    }
}
