//! Majority-vote polynomials over `F_p` — the paper's core contribution
//! (Section III-B, Lemma 1, Table III).
//!
//! For `n` users each holding a sign `xᵢ ∈ {−1,+1}`, the aggregate
//! `x = Σ xᵢ` lies in the support `S = {−n, −n+2, …, n}`. Fermat's Little
//! Theorem gives an exact indicator `1 − (x−m)^(p−1) = [x = m]` over `F_p`
//! (`p > n` prime), so
//!
//! ```text
//! F(x) = Σ_{m ∈ S} sign(m) · (1 − (x−m)^(p−1))   (mod p)      — Eq. (1)
//! ```
//!
//! satisfies `F(Σ xᵢ) = sign(Σ xᵢ)` (Lemma 1). Off the support, every
//! indicator vanishes, so `F ≡ 0` there: `F` is *exactly* the interpolation
//! of `sign` on `S` and `0` on `F_p \ S`. We implement both constructions —
//! symbolic expansion of Eq. (1) and full-domain Lagrange interpolation —
//! and test they coincide (and reproduce Table III coefficient-for-
//! coefficient).
//!
//! The module also builds the **power schedule** (Eq. 2): which Beaver
//! multiplications Algorithm 1 performs to obtain shares of
//! `x², …, x^deg(F)`, with the `v_k = 2^⌊log₂(k−1)⌋` decomposition, plus a
//! *sparse* schedule ablation that only computes the powers with nonzero
//! coefficients.

use crate::field::{next_prime, Fp};

/// Tie-breaking policy for the majority vote (Section III-E).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TiePolicy {
    /// `sign(0) ∈ {−1, +1}` — 1-bit output. The paper's Table III resolves
    /// ties to **−1** (e.g. n=2: F(0) = 2 ≡ −1 mod 3); we follow that.
    OneBit,
    /// `sign(0) = 0` — three-state output (2 bits).
    TwoBit,
}

impl TiePolicy {
    /// The vote value assigned to a zero aggregate.
    pub fn tie_value(self) -> i64 {
        match self {
            TiePolicy::OneBit => -1,
            TiePolicy::TwoBit => 0,
        }
    }

    /// sign with this policy applied at zero.
    pub fn sign(self, x: i64) -> i64 {
        if x > 0 {
            1
        } else if x < 0 {
            -1
        } else {
            self.tie_value()
        }
    }

    /// Downlink bits per coordinate for the *global* vote under this policy
    /// (Section III-E: 1-bit vs 2-bit downlink).
    pub fn downlink_bits(self) -> u32 {
        match self {
            TiePolicy::OneBit => 1,
            TiePolicy::TwoBit => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TiePolicy::OneBit => "one_bit",
            TiePolicy::TwoBit => "two_bit",
        }
    }

    pub fn from_name(s: &str) -> Option<TiePolicy> {
        match s {
            "one_bit" | "1bit" | "A" => Some(TiePolicy::OneBit),
            "two_bit" | "2bit" | "B" => Some(TiePolicy::TwoBit),
            _ => None,
        }
    }
}

/// Dense polynomial over `F_p`: `coeffs[k]` is the coefficient of `x^k`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Poly {
    pub fp: Fp,
    pub coeffs: Vec<u64>,
}

impl Poly {
    pub fn zero(fp: Fp) -> Poly {
        Poly { fp, coeffs: vec![] }
    }

    pub fn constant(fp: Fp, c: u64) -> Poly {
        let mut p = Poly { fp, coeffs: vec![fp.reduce(c)] };
        p.trim();
        p
    }

    fn trim(&mut self) {
        while self.coeffs.last() == Some(&0) {
            self.coeffs.pop();
        }
    }

    /// Degree; `None` for the zero polynomial.
    pub fn degree(&self) -> Option<usize> {
        if self.coeffs.is_empty() {
            None
        } else {
            Some(self.coeffs.len() - 1)
        }
    }

    /// `self += k · other`.
    pub fn add_scaled(&mut self, k: u64, other: &Poly) {
        let f = self.fp;
        if self.coeffs.len() < other.coeffs.len() {
            self.coeffs.resize(other.coeffs.len(), 0);
        }
        for (i, &c) in other.coeffs.iter().enumerate() {
            self.coeffs[i] = f.add(self.coeffs[i], f.mul(k, c));
        }
        self.trim();
    }

    /// Multiply in place by the monic linear factor `(x − m)`.
    pub fn mul_linear(&mut self, m: u64) {
        let f = self.fp;
        let neg_m = f.neg(f.reduce(m));
        let n = self.coeffs.len();
        self.coeffs.push(0);
        // (c_0 + c_1 x + ...)(x − m): new_k = c_{k−1} − m·c_k
        for k in (0..=n).rev() {
            let shifted = if k > 0 { self.coeffs[k - 1] } else { 0 };
            let scaled = f.mul(neg_m, if k < n { self.coeffs[k] } else { 0 });
            self.coeffs[k] = f.add(shifted, scaled);
        }
        self.trim();
    }

    /// Horner evaluation at a canonical field element.
    pub fn eval(&self, x: u64) -> u64 {
        let f = self.fp;
        debug_assert!(x < f.modulus());
        let mut acc = 0u64;
        for &c in self.coeffs.iter().rev() {
            acc = f.add(f.mul(acc, x), c);
        }
        acc
    }

    /// Vectorized Horner evaluation: `out[j] = F(xs[j])` for canonical
    /// inputs. This is the plaintext analogue of the L1 Pallas kernel and
    /// the server-side vote readout hot path.
    pub fn eval_vec(&self, xs: &[u64]) -> Vec<u64> {
        let f = self.fp;
        let mut acc = vec![0u64; xs.len()];
        for &c in self.coeffs.iter().rev() {
            for (a, &x) in acc.iter_mut().zip(xs) {
                *a = f.add(f.reduce(*a * x), c);
            }
        }
        acc
    }

    /// Indices of nonzero coefficients with power ≥ 2 (the powers the
    /// sparse schedule must produce).
    pub fn needed_powers(&self) -> Vec<usize> {
        self.coeffs
            .iter()
            .enumerate()
            .skip(2)
            .filter(|(_, &c)| c != 0)
            .map(|(k, _)| k)
            .collect()
    }

    /// Render like Table III: e.g. `2x^3 + 4x (mod 5)`.
    pub fn display(&self) -> String {
        if self.coeffs.is_empty() {
            return format!("0 (mod {})", self.fp.modulus());
        }
        let mut terms: Vec<String> = Vec::new();
        for (k, &c) in self.coeffs.iter().enumerate().rev() {
            if c == 0 {
                continue;
            }
            let t = match (k, c) {
                (0, c) => format!("{c}"),
                (1, 1) => "x".to_string(),
                (1, c) => format!("{c}x"),
                (k, 1) => format!("x^{k}"),
                (k, c) => format!("{c}x^{k}"),
            };
            terms.push(t);
        }
        format!("{} (mod {})", terms.join(" + "), self.fp.modulus())
    }
}

/// The majority-vote polynomial for a (sub)group of `n` users, together
/// with the metadata the protocol and cost model need.
#[derive(Debug, Clone)]
pub struct MvPolynomial {
    /// Group size.
    pub n: usize,
    /// Tie policy it encodes.
    pub policy: TiePolicy,
    /// Quantization precision (number of levels; 2 = sign vote).
    pub q: u8,
    /// `F_p` with `p = next_prime(n·(q−1))` (`next_prime(n)` at q = 2).
    pub fp: Fp,
    /// The polynomial itself.
    pub poly: Poly,
}

impl MvPolynomial {
    /// Construct via symbolic expansion of Eq. (1) — the paper's
    /// construction. Cost `O(n · p²)` coefficient ops (Table IV's
    /// `O(n log p)` counts modular exponentiations; we expand symbolically
    /// once offline, which is still sub-millisecond for `p ≤ 101`).
    pub fn build_fermat(n: usize, policy: TiePolicy) -> MvPolynomial {
        assert!(n >= 1, "group size must be ≥ 1");
        // p must be an ODD prime > n: the support {−n..n step 2} is only
        // pairwise distinct mod p when p ∤ 2k for 0 < k ≤ n, which needs
        // p odd. next_prime(n) is odd for all n ≥ 2; n = 1 would give
        // p = 2 (degenerate: +1 ≡ −1), so we clamp to p = 3.
        let fp = Fp::new(next_prime(n.max(2) as u64));
        let p = fp.modulus();
        let mut acc = Poly::zero(fp);
        // support m ∈ {−n, −n+2, …, n}
        let mut m = -(n as i64);
        while m <= n as i64 {
            let s = policy.sign(m);
            if s != 0 {
                // indicator = 1 − (x − m)^(p−1)
                let mut ind = Poly::constant(fp, 1);
                let m_f = fp.from_i64(m);
                for _ in 0..p - 1 {
                    ind.mul_linear(m_f);
                }
                // ind now = (x−m)^(p−1); accumulate sign·(1 − ind)
                let s_f = fp.from_i64(s);
                acc.add_scaled(s_f, &Poly::constant(fp, 1));
                acc.add_scaled(fp.neg(s_f), &ind);
            }
            m += 2;
        }
        MvPolynomial { n, policy, q: 2, fp, poly: acc }
    }

    /// Generalized q-level construction: interpolate the quantized
    /// aggregate `g(s)` ([`crate::quant::quant_aggregate`]) on the sum
    /// support `S_q = {−n(q−1), …, n(q−1) step 2}` via the same Fermat
    /// indicators as [`Self::build_fermat`], over
    /// `p = next_prime(max(n,2)·(q−1))`.
    ///
    /// At `q = 2` the field, the support, and the target map all collapse
    /// to the sign-vote construction, so the coefficients equal
    /// [`Self::build_fermat`]'s exactly (pinned by
    /// `fermat_q2_equals_legacy` below) — the q = 2 quant path IS the
    /// legacy path, dealer streams and all.
    pub fn build_fermat_q(n: usize, q: u8, policy: TiePolicy) -> MvPolynomial {
        assert!(n >= 1, "group size must be ≥ 1");
        crate::quant::validate_precision(q);
        let qm1 = q as u64 - 1;
        // Same primality requirements as build_fermat, scaled: the
        // support has n(q−1)+1 points spaced 2 apart, pairwise distinct
        // mod p for odd p > n(q−1); max(n,2) also guarantees
        // p > 2(q−1), so every output level lifts unambiguously.
        let fp = Fp::new(next_prime(n.max(2) as u64 * qm1));
        let p = fp.modulus();
        let mut acc = Poly::zero(fp);
        let hi = (n as i64) * qm1 as i64;
        let mut m = -hi;
        while m <= hi {
            let v = crate::quant::quant_aggregate(m, n, q, policy);
            if v != 0 {
                // indicator = 1 − (x − m)^(p−1), scaled by the level
                let mut ind = Poly::constant(fp, 1);
                let m_f = fp.from_i64(m);
                for _ in 0..p - 1 {
                    ind.mul_linear(m_f);
                }
                let v_f = fp.from_i64(v);
                acc.add_scaled(v_f, &Poly::constant(fp, 1));
                acc.add_scaled(fp.neg(v_f), &ind);
            }
            m += 2;
        }
        MvPolynomial { n, policy, q, fp, poly: acc }
    }

    /// Lagrange cross-check for [`Self::build_fermat_q`]: full-domain
    /// interpolation of `g` on `S_q` and 0 elsewhere.
    pub fn build_lagrange_q(n: usize, q: u8, policy: TiePolicy) -> MvPolynomial {
        assert!(n >= 1, "group size must be ≥ 1");
        crate::quant::validate_precision(q);
        let qm1 = q as u64 - 1;
        let fp = Fp::new(next_prime(n.max(2) as u64 * qm1));
        let p = fp.modulus();
        let mut target = vec![0u64; p as usize];
        let hi = (n as i64) * qm1 as i64;
        let mut m = -hi;
        while m <= hi {
            let v = crate::quant::quant_aggregate(m, n, q, policy);
            target[fp.from_i64(m) as usize] = fp.from_i64(v);
            m += 2;
        }
        let mut acc = Poly::zero(fp);
        for v in 0..p {
            let t = target[v as usize];
            if t == 0 {
                continue;
            }
            let mut basis = Poly::constant(fp, 1);
            let mut denom = 1u64;
            for w in 0..p {
                if w == v {
                    continue;
                }
                basis.mul_linear(w);
                denom = fp.mul(denom, fp.sub(v, w));
            }
            let k = fp.mul(t, fp.inv(denom));
            acc.add_scaled(k, &basis);
        }
        MvPolynomial { n, policy, q, fp, poly: acc }
    }

    /// Construct via full-domain Lagrange interpolation of the target
    /// function (sign on the support, 0 elsewhere). Must equal
    /// [`Self::build_fermat`] — the equality is a correctness test.
    pub fn build_lagrange(n: usize, policy: TiePolicy) -> MvPolynomial {
        let fp = Fp::new(next_prime(n.max(2) as u64)); // odd prime; see build_fermat

        let p = fp.modulus();
        // Targets over all residues.
        let mut target = vec![0u64; p as usize];
        let mut m = -(n as i64);
        while m <= n as i64 {
            target[fp.from_i64(m) as usize] = fp.from_i64(policy.sign(m));
            m += 2;
        }
        // Lagrange: F = Σ_v target[v] · L_v where
        // L_v(x) = Π_{w≠v} (x−w)/(v−w).
        let mut acc = Poly::zero(fp);
        for v in 0..p {
            let t = target[v as usize];
            if t == 0 {
                continue;
            }
            let mut basis = Poly::constant(fp, 1);
            let mut denom = 1u64;
            for w in 0..p {
                if w == v {
                    continue;
                }
                basis.mul_linear(w);
                denom = fp.mul(denom, fp.sub(v, w));
            }
            let k = fp.mul(t, fp.inv(denom));
            acc.add_scaled(k, &basis);
        }
        MvPolynomial { n, policy, q: 2, fp, poly: acc }
    }

    /// Degree of F (0 for a constant/zero polynomial).
    pub fn degree(&self) -> usize {
        self.poly.degree().unwrap_or(0)
    }

    /// Evaluate the vote on a *plaintext* aggregate sum (for testing and
    /// the non-private baseline): input is the signed sum `Σ xᵢ`.
    pub fn vote_of_sum(&self, sum: i64) -> i64 {
        let x = self.fp.from_i64(sum);
        self.fp.lift(self.poly.eval(x))
    }

    /// Ground-truth majority vote with this policy — what Lemma 1 says
    /// `vote_of_sum` must equal on the support. For a q-level polynomial
    /// this is the quantized aggregate (the sign at `q = 2`).
    pub fn expected_vote(&self, sum: i64) -> i64 {
        crate::quant::quant_aggregate(sum, self.n, self.q, self.policy)
    }
}

// --------------------------------------------------------- power schedule

/// One secure multiplication in the power schedule: produce the share of
/// `x^target` as `x^left · x^right` (Eq. 2: `left = k − v_k`,
/// `right = v_k`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PowerStep {
    pub target: usize,
    pub left: usize,
    pub right: usize,
    /// Serial subround index (0-based): all steps with the same depth can
    /// be batched into one uplink/downlink exchange.
    pub depth: usize,
}

/// The multiplication schedule for securely evaluating `F(x)`.
#[derive(Debug, Clone)]
pub struct PowerSchedule {
    pub steps: Vec<PowerStep>,
    /// Highest power produced.
    pub max_power: usize,
}

impl PowerSchedule {
    /// The paper's Algorithm-1 schedule: every power `k = 2..=deg`, with
    /// `v_k = 2^⌊log₂(k−1)⌋`, `x^k = x^(k−v_k) · x^(v_k)`.
    pub fn full(deg: usize) -> PowerSchedule {
        let mut steps = Vec::new();
        let mut depth_of = vec![0usize; deg.max(1) + 1];
        for k in 2..=deg {
            let v = 1usize << (usize::BITS - 1 - (k as u64 - 1).leading_zeros().min(63)) as usize;
            let v = v.min(k - 1);
            let (l, r) = (k - v, v);
            let d = 1 + depth_of[l].max(depth_of[r]);
            depth_of[k] = d;
            steps.push(PowerStep { target: k, left: l, right: r, depth: d - 1 });
        }
        PowerSchedule { steps, max_power: deg }
    }

    /// Sparse-schedule ablation: only produce the powers in `needed`
    /// (plus the intermediates of a binary addition chain). Reduces `R`
    /// for odd-sparse polynomials (e.g. n odd ⇒ only odd powers needed).
    pub fn sparse(needed: &[usize]) -> PowerSchedule {
        use std::collections::BTreeMap;
        let mut depth_of: BTreeMap<usize, usize> = BTreeMap::new();
        depth_of.insert(1, 0);
        let mut steps = Vec::new();
        fn ensure(
            k: usize,
            depth_of: &mut BTreeMap<usize, usize>,
            steps: &mut Vec<PowerStep>,
        ) -> usize {
            if let Some(&d) = depth_of.get(&k) {
                return d;
            }
            // split k = l + r, r the largest power of two ≤ k−1 (mirrors
            // Eq. 2 but skips unneeded intermediates).
            let v = 1usize << (usize::BITS - 1 - (k as u64 - 1).leading_zeros().min(63)) as usize;
            let v = v.min(k - 1);
            let (l, r) = (k - v, v);
            let dl = ensure(l, depth_of, steps);
            let dr = ensure(r, depth_of, steps);
            let d = 1 + dl.max(dr);
            depth_of.insert(k, d);
            steps.push(PowerStep { target: k, left: l, right: r, depth: d - 1 });
            d
        }
        let mut queue: Vec<usize> = needed.to_vec();
        queue.sort_unstable();
        for k in queue {
            if k >= 2 {
                ensure(k, &mut depth_of, &mut steps);
            }
        }
        steps.sort_by_key(|s| (s.depth, s.target));
        let max_power = steps.iter().map(|s| s.target).max().unwrap_or(1);
        PowerSchedule { steps, max_power }
    }

    /// Number of secure multiplications (Beaver triples consumed).
    pub fn mults(&self) -> usize {
        self.steps.len()
    }

    /// Number of masked field elements each user uploads — two openings
    /// (δ-share, ε-share) per multiplication. This is the paper's `R`
    /// column in Tables VIII/IX (their `C_u = R·⌈log p₁⌉` only matches the
    /// protocol's real uplink if `R` counts openings, not triples).
    pub fn openings(&self) -> usize {
        2 * self.steps.len()
    }

    /// Serial depth: number of sequential subrounds (server round-trips)
    /// needed. Steps at equal depth batch into one exchange.
    pub fn depth(&self) -> usize {
        self.steps.iter().map(|s| s.depth + 1).max().unwrap_or(0)
    }

    /// Steps grouped by subround, in execution order.
    pub fn by_depth(&self) -> Vec<Vec<PowerStep>> {
        let d = self.depth();
        let mut groups = vec![Vec::new(); d];
        for s in &self.steps {
            groups[s.depth].push(*s);
        }
        groups
    }
}

/// Convenience: full-schedule stats for a group of `n` users under a
/// policy — (degree, mults, openings, depth).
pub fn schedule_stats(n: usize, policy: TiePolicy) -> (usize, usize, usize, usize) {
    let mv = MvPolynomial::build_fermat(n, policy);
    let sched = PowerSchedule::full(mv.degree());
    (mv.degree(), sched.mults(), sched.openings(), sched.depth())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table III, exact coefficients. Keys: (n, policy) → coeff vec
    /// (index = power).
    #[test]
    fn table3_exact() {
        let cases: Vec<(usize, TiePolicy, Vec<u64>)> = vec![
            (2, TiePolicy::OneBit, vec![2, 2, 1]),          // x²+2x+2 mod 3
            (2, TiePolicy::TwoBit, vec![0, 2]),             // 2x mod 3
            (3, TiePolicy::OneBit, vec![0, 4, 0, 2]),       // 2x³+4x mod 5
            (3, TiePolicy::TwoBit, vec![0, 4, 0, 2]),       // same (no tie for odd n)
            (4, TiePolicy::OneBit, vec![4, 1, 0, 3, 1]),    // x⁴+3x³+x+4 mod 5
            (4, TiePolicy::TwoBit, vec![0, 1, 0, 3]),       // 3x³+x mod 5
            (5, TiePolicy::OneBit, vec![0, 3, 0, 2, 0, 3]), // 3x⁵+2x³+3x mod 7
            (5, TiePolicy::TwoBit, vec![0, 3, 0, 2, 0, 3]),
            (6, TiePolicy::OneBit, vec![6, 4, 0, 5, 0, 4, 1]), // x⁶+4x⁵+5x³+4x+6 mod 7
        ];
        for (n, policy, want) in cases {
            let mv = MvPolynomial::build_fermat(n, policy);
            assert_eq!(
                mv.poly.coeffs, want,
                "Table III mismatch for n={n} policy={policy:?} (got {})",
                mv.poly.display()
            );
        }
    }

    #[test]
    fn fermat_equals_lagrange() {
        for n in 1..=16 {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let a = MvPolynomial::build_fermat(n, policy);
                let b = MvPolynomial::build_lagrange(n, policy);
                assert_eq!(
                    a.poly.coeffs, b.poly.coeffs,
                    "constructions differ for n={n} {policy:?}"
                );
            }
        }
        // …and the q-level generalization, for every supported precision
        // and both tie policies (smaller n range: p grows with n·(q−1)).
        for q in crate::quant::PRECISIONS {
            for n in 1..=6 {
                for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                    let a = MvPolynomial::build_fermat_q(n, q, policy);
                    let b = MvPolynomial::build_lagrange_q(n, q, policy);
                    assert_eq!(a.fp.modulus(), b.fp.modulus());
                    assert_eq!(
                        a.poly.coeffs, b.poly.coeffs,
                        "q-level constructions differ for n={n} q={q} {policy:?}"
                    );
                }
            }
        }
    }

    /// The q = 2 quant polynomial IS the legacy sign-vote polynomial:
    /// same prime, same coefficients — so every downstream consumer
    /// (EvalPlan, schedules, dealer streams) is byte-identical.
    #[test]
    fn fermat_q2_equals_legacy() {
        for n in 1..=16 {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let legacy = MvPolynomial::build_fermat(n, policy);
                let quant = MvPolynomial::build_fermat_q(n, 2, policy);
                assert_eq!(legacy.fp.modulus(), quant.fp.modulus(), "n={n} {policy:?}");
                assert_eq!(legacy.poly.coeffs, quant.poly.coeffs, "n={n} {policy:?}");
            }
        }
    }

    /// Lemma 1 generalized: F_q(Σxᵢ) equals the quantized aggregate for
    /// every achievable sum — exhaustive over the q-level support.
    #[test]
    fn lemma1_quantized_exhaustive() {
        for q in crate::quant::PRECISIONS {
            for n in 1..=5usize {
                for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                    let mv = MvPolynomial::build_fermat_q(n, q, policy);
                    let hi = n as i64 * (q as i64 - 1);
                    let mut sum = -hi;
                    while sum <= hi {
                        assert_eq!(
                            mv.vote_of_sum(sum),
                            crate::quant::quant_aggregate(sum, n, q, policy),
                            "q={q} n={n} {policy:?} sum={sum}"
                        );
                        sum += 2;
                    }
                }
            }
        }
    }

    /// Lemma 1: F(Σxᵢ) = sign(Σxᵢ) for every achievable sum, every n up to
    /// 24, both policies — exhaustive over the support.
    #[test]
    fn lemma1_exhaustive() {
        for n in 1..=24 {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let mv = MvPolynomial::build_fermat(n, policy);
                let mut sum = -(n as i64);
                while sum <= n as i64 {
                    assert_eq!(
                        mv.vote_of_sum(sum),
                        mv.expected_vote(sum),
                        "n={n} {policy:?} sum={sum}"
                    );
                    sum += 2;
                }
            }
        }
    }

    /// Off-support values evaluate to 0 (Eq. (1) indicator structure) —
    /// relevant because it means a malformed aggregate is *detectable*.
    #[test]
    fn off_support_is_zero() {
        let mv = MvPolynomial::build_fermat(3, TiePolicy::OneBit); // p=5
        // support ≡ {2,4,1,3}; off-support {0}
        assert_eq!(mv.poly.eval(0), 0);
        let mv = MvPolynomial::build_fermat(7, TiePolicy::OneBit); // p=11
        // support {−7..7 step2} ≡ {4,6,8,10,1,3,5,7}; off: {0,2,9}
        for x in [0u64, 2, 9] {
            assert_eq!(mv.poly.eval(x), 0, "x={x}");
        }
    }

    #[test]
    fn odd_n_polynomials_are_odd_functions() {
        // For odd n (no tie possible) both policies coincide and F is an
        // odd polynomial (only odd powers) — this is what makes the sparse
        // schedule pay off.
        for n in [3usize, 5, 7, 9, 11, 15] {
            let mv = MvPolynomial::build_fermat(n, TiePolicy::OneBit);
            for (k, &c) in mv.poly.coeffs.iter().enumerate() {
                if k % 2 == 0 {
                    assert_eq!(c, 0, "n={n}: even coeff x^{k} = {c} ≠ 0");
                }
            }
        }
    }

    #[test]
    fn eval_vec_matches_scalar() {
        let mv = MvPolynomial::build_fermat(8, TiePolicy::OneBit);
        let p = mv.fp.modulus();
        let xs: Vec<u64> = (0..p).collect();
        let v = mv.poly.eval_vec(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(v[i], mv.poly.eval(x));
        }
    }

    #[test]
    fn full_schedule_shape() {
        // deg 3 (n=3): x² = x·x (depth 1), x³ = x¹·x² (depth 2) — the
        // Appendix-A example's two subrounds.
        let s = PowerSchedule::full(3);
        assert_eq!(s.mults(), 2);
        assert_eq!(s.openings(), 4); // paper's R for n₁=3
        assert_eq!(s.depth(), 2);
        assert_eq!(
            s.steps,
            vec![
                PowerStep { target: 2, left: 1, right: 1, depth: 0 },
                PowerStep { target: 3, left: 1, right: 2, depth: 1 },
            ]
        );
    }

    #[test]
    fn schedule_depth_lower_bound() {
        // After r subrounds the max achievable power is 2^r, so
        // depth ≥ ⌈log₂ deg⌉. The full schedule should be within +1 of it.
        for deg in 2..=101usize {
            let s = PowerSchedule::full(deg);
            let lb = (usize::BITS - (deg - 1).leading_zeros()) as usize;
            assert!(
                s.depth() <= lb + 1,
                "deg={deg}: depth {} > {}+1",
                s.depth(),
                lb
            );
            // every left/right operand is produced before use
            let mut depth_of = std::collections::BTreeMap::new();
            depth_of.insert(1usize, 0usize);
            for st in &s.steps {
                let dl = *depth_of.get(&st.left).expect("left exists");
                let dr = *depth_of.get(&st.right).expect("right exists");
                assert!(st.depth >= dl.max(dr), "step {st:?}");
                depth_of.insert(st.target, st.depth + 1);
            }
        }
    }

    #[test]
    fn sparse_schedule_covers_needed_and_is_smaller() {
        // n=5: F = 3x⁵+2x³+3x (mod 7): needed powers {3,5}.
        let mv = MvPolynomial::build_fermat(5, TiePolicy::OneBit);
        assert_eq!(mv.poly.needed_powers(), vec![3, 5]);
        let sparse = PowerSchedule::sparse(&mv.poly.needed_powers());
        let full = PowerSchedule::full(mv.degree());
        let produced: Vec<usize> = sparse.steps.iter().map(|s| s.target).collect();
        for k in mv.poly.needed_powers() {
            assert!(produced.contains(&k), "missing x^{k}");
        }
        assert!(sparse.mults() <= full.mults());
        // every operand available when used
        let mut have = std::collections::BTreeSet::new();
        have.insert(1usize);
        for st in &sparse.steps {
            assert!(
                have.contains(&st.left) && have.contains(&st.right),
                "{st:?}"
            );
            have.insert(st.target);
        }
    }

    #[test]
    fn degrees_bounded_by_field() {
        for n in [3usize, 4, 5, 6, 8, 10, 12, 24] {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let mv = MvPolynomial::build_fermat(n, policy);
                assert!(
                    mv.degree() <= mv.fp.modulus() as usize - 1,
                    "n={n} {policy:?}"
                );
            }
        }
    }

    #[test]
    fn display_matches_table3_style() {
        let mv = MvPolynomial::build_fermat(3, TiePolicy::OneBit);
        assert_eq!(mv.poly.display(), "2x^3 + 4x (mod 5)");
        let mv = MvPolynomial::build_fermat(2, TiePolicy::OneBit);
        assert_eq!(mv.poly.display(), "x^2 + 2x + 2 (mod 3)");
    }
}
