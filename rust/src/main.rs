//! `hisafe` — the Hi-SAFE launcher.
//!
//! ```text
//! hisafe presets                      list built-in experiment presets
//! hisafe train --preset fig2a        run a figure experiment (all seeds)
//! hisafe train --config cfg.json     run a custom experiment
//! hisafe poly --n 6                  print majority-vote polynomials (Table III)
//! hisafe tables                      regenerate Tables VII/VIII/IX
//! hisafe fig6                        regenerate Fig. 6 series
//! hisafe security --n 24 --ell 8     leakage + uniformity analysis
//! hisafe sweep --tenants 24x8@3,12x4 multi-tenant scheduler sweep (QoS-aware)
//! hisafe serve --shards 2            sharded aggregation service on loopback TCP
//! hisafe balance --hosts A:P,B:P     fail-over balancer over several serve hosts
//! hisafe sweep --remote 127.0.0.1:7433  the same sweep, driven over the wire
//! hisafe sweep --chaos-seed 7        one seeded fault schedule on a real cluster
//! hisafe demo                        Appendix-A walkthrough (n=3)
//! ```

use hisafe::config::{preset, preset_names, ExperimentConfig};
use hisafe::cost;
use hisafe::engine::{AdmissionError, AggScheduler, QosPolicy, SessionId};
use hisafe::fl::data::{partition_users, synthetic};
use hisafe::fl::model::{LinearSoftmax, Mlp};
use hisafe::fl::trainer::{train, TrainConfig, TrainResult};
use hisafe::metrics::CommStats;
use hisafe::poly::{MvPolynomial, TiePolicy};
use hisafe::protocol::{
    plain_quant_aggregate, plain_quant_aggregate_present, HiSafeConfig, ParticipantSet,
};
use hisafe::security;
use hisafe::service::{
    AggFrontend, Balancer, Codec, ServiceClient, ServiceServer, PROTOCOL_VERSION,
};
use hisafe::util::cli::Args;
use hisafe::util::json::Json;

fn main() {
    let args = match Args::from_env(&["verbose", "threaded", "jax", "stop-server"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "presets" => cmd_presets(),
        "train" => cmd_train(&args),
        "poly" => cmd_poly(&args),
        "tables" => cmd_tables(&args),
        "fig6" => cmd_fig6(),
        "security" => cmd_security(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "balance" => cmd_balance(&args),
        "demo" => cmd_demo(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "hisafe — Hierarchical Secure Aggregation for Lightweight FL\n\
         \n\
         commands:\n\
           presets                         list experiment presets\n\
           train --preset <name> [--rounds N] [--seed S] [--out DIR] [--verbose]\n\
           train --config <file.json>\n\
           poly --n <users> [--policy one_bit|two_bit]\n\
           tables [--policy one_bit]       Tables VII/VIII/IX\n\
           fig6                            Fig. 6 cost/latency series\n\
           security [--n 24] [--ell 8]     leakage analysis\n\
           sweep [--tenants 24x8x2048@3@q4,...] [--rounds 5] [--threads N] [--out DIR]\n\
                 [--rps R] [--tps T] [--queue-depth Q] [--churn P] [--precision Q]\n\
                                           mixed-tenant scheduler workload with\n\
                                           per-tenant QoS (@W = dealing weight;\n\
                                           @qQ = quantization precision 2|4|8|16,\n\
                                           --precision sets the default;\n\
                                           rps/tps/queue-depth bound every tenant;\n\
                                           churn P drops each user per round with\n\
                                           probability P — below-threshold rounds\n\
                                           abort, survivors are reported)\n\
           sweep --remote HOST:PORT [--codec json|binary] [--stop-server]\n\
                                           the same sweep driven over the wire\n\
                                           against a `hisafe serve` process\n\
                                           (--codec binary negotiates the v2\n\
                                           length-prefixed framing; default json;\n\
                                           the report adds bytes/round)\n\
           sweep --chaos-seed S            one deterministic fault schedule (host\n\
                                           kill + revive, frame corruption,\n\
                                           balancer restart, shard poison...)\n\
                                           against an in-process cluster; replays\n\
                                           the seed a chaos_props failure prints\n\
           serve [--addr 127.0.0.1:7433] [--shards 2] [--threads 2] [--max-tenants M]\n\
                 [--workers W] [--codec json|binary]\n\
                                           sharded aggregation service over TCP:\n\
                                           JSON frames by default per connection,\n\
                                           acking the v2 binary framing when a\n\
                                           client asks (unless --codec json); W\n\
                                           bounded connection workers, default 4\n\
           balance --hosts A:P,B:P [--addr 127.0.0.1:7432] [--health-ms 250]\n\
                 [--codec json|binary]     fail-over balancer fronting several\n\
                                           serve hosts: health checks, dead-host\n\
                                           detection, snapshot-based session\n\
                                           fail-over (votes stay bit-identical)\n\
           demo                            Appendix-A walkthrough"
    );
}

fn cmd_presets() -> Result<(), String> {
    println!(
        "{:<18} {:<12} {:<10} {:>4} {:>7} {}",
        "name", "dataset", "partition", "n", "rounds", "aggregator"
    );
    for name in preset_names() {
        let c = preset(name).unwrap();
        println!(
            "{:<18} {:<12} {:<10} {:>4} {:>7} {}",
            c.name,
            c.dataset.name(),
            c.partition.name(),
            c.participants,
            c.rounds,
            c.aggregator().name()
        );
    }
    Ok(())
}

/// Resolve + run one experiment config (all seeds); returns per-seed results.
fn run_experiment(cfg: &ExperimentConfig, rounds_override: Option<usize>) -> Vec<TrainResult> {
    let (tr, te) = synthetic(cfg.dataset, cfg.n_train, cfg.n_test, 1234);
    let mut results = Vec::new();
    for &seed in &cfg.seeds {
        let shards = partition_users(&tr, cfg.n_users, cfg.partition, seed ^ 0xdead);
        let tc = TrainConfig {
            n_users: cfg.n_users,
            participants: cfg.participants,
            rounds: rounds_override.unwrap_or(cfg.rounds),
            lr: cfg.lr as f32,
            batch_size: cfg.batch_size,
            eval_every: cfg.eval_every,
            seed,
            churn: 0.0,
        };
        let agg = cfg.aggregator();
        let res = match cfg.model.as_str() {
            "linear" => {
                let m = LinearSoftmax::new(tr.dim, tr.n_classes);
                train(&m, &tr, &te, &shards, agg, &tc)
            }
            m if m.starts_with("mlp_") => {
                let hidden: usize = m[4..].parse().expect("mlp_<hidden>");
                let m = Mlp::new(tr.dim, hidden, tr.n_classes);
                train(&m, &tr, &te, &shards, agg, &tc)
            }
            other => panic!("unknown model '{other}'"),
        };
        results.push(res);
    }
    results
}

fn cmd_train(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "preset", "config", "rounds", "seed", "out", "verbose", "threaded", "jax",
    ])?;
    let mut cfg = if let Some(p) = args.get("preset") {
        preset(p).ok_or_else(|| format!("unknown preset '{p}'; try `hisafe presets`"))?
    } else if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(path)?
    } else {
        return Err("train needs --preset or --config".into());
    };
    if let Some(s) = args.get("seed") {
        cfg.seeds = vec![s.parse().map_err(|_| "--seed must be u64")?];
    }
    let rounds = args
        .get("rounds")
        .map(|r| r.parse::<usize>().map_err(|_| "--rounds must be usize"))
        .transpose()?;
    println!(
        "# experiment {} — dataset {}, {} users ({} participate), agg {}",
        cfg.name,
        cfg.dataset.name(),
        cfg.n_users,
        cfg.participants,
        cfg.aggregator().name()
    );
    let t0 = std::time::Instant::now();
    let results = run_experiment(&cfg, rounds);
    let mean_acc: f32 =
        results.iter().map(|r| r.final_acc).sum::<f32>() / results.len() as f32;
    for (i, r) in results.iter().enumerate() {
        println!(
            "seed {}: final acc {:.4}  (per-user uplink {} bits total)",
            cfg.seeds[i], r.final_acc, r.total_uplink_bits_per_user
        );
        if args.has("verbose") {
            for l in r.logs.iter().filter(|l| l.round % cfg.eval_every == 0) {
                println!(
                    "  round {:>4}  loss {:.4}  acc {:.4}",
                    l.round, l.train_loss, l.test_acc
                );
            }
        }
    }
    println!(
        "mean final acc over {} seeds: {:.4}  ({:.1}s)",
        results.len(),
        mean_acc,
        t0.elapsed().as_secs_f64()
    );
    // persist curves
    let out_dir = args.get_or("out", "runs");
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    for (i, r) in results.iter().enumerate() {
        let path = format!("{out_dir}/{}_seed{}.json", cfg.name, cfg.seeds[i]);
        std::fs::write(&path, r.to_json().to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_poly(args: &Args) -> Result<(), String> {
    args.check_known(&["n", "policy"])?;
    let n = args.get_usize("n", 6)?;
    match args.get("policy") {
        Some(p) => {
            let policy =
                TiePolicy::from_name(p).ok_or("policy must be one_bit|two_bit")?;
            let mv = MvPolynomial::build_fermat(n, policy);
            println!("n={n} {}: F(x) = {}", policy.name(), mv.poly.display());
        }
        None => {
            // Table III style: both policies for 2..=n
            println!(
                "{:<6} {:<42} {}",
                "#users", "sign(0) ∈ {−1,+1}", "sign(0) = 0"
            );
            for k in 2..=n {
                let a = MvPolynomial::build_fermat(k, TiePolicy::OneBit);
                let b = MvPolynomial::build_fermat(k, TiePolicy::TwoBit);
                println!("n={:<4} {:<42} {}", k, a.poly.display(), b.poly.display());
            }
        }
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<(), String> {
    args.check_known(&["policy"])?;
    let policy = TiePolicy::from_name(args.get_or("policy", "one_bit"))
        .ok_or("policy must be one_bit|two_bit")?;
    println!("=== Table VII: optimal subgroup configurations ===");
    println!(
        "{:>4} {:>4} {:>4} {:>6} {:>6} {:>6} {:>10} {:>10}",
        "n", "l*", "n1", "depth", "R", "C_u", "C_T", "C_T red%"
    );
    for n in [24usize, 36, 60, 90, 100] {
        let best = cost::optimal_ell(n, policy, false);
        let flat = cost::config_cost(n, 1, policy, false);
        println!(
            "{:>4} {:>4} {:>4} {:>6} {:>6} {:>6} {:>10} {:>9.1}%",
            n,
            best.ell,
            best.group.n1,
            best.group.depth,
            best.group.openings,
            best.group.c_u_bits,
            best.c_t_bits,
            cost::reduction_pct(flat.c_t_bits, best.c_t_bits)
        );
    }
    println!("\n=== Tables VIII/IX: full sweep (ours vs paper) ===");
    println!(
        "{:>4} {:>4} {:>4} {:>4} {:>6} {:>5} {:>6} {:>6} | {:>6} {:>6} {:>6}",
        "n", "l", "n1", "p1", "logp", "depth", "R", "C_u", "R_pap", "Cu_pap", "CT_pap"
    );
    for row in cost::paper_tables() {
        if row.n % row.ell != 0 {
            continue;
        }
        let c = cost::config_cost(row.n, row.ell, policy, false);
        println!(
            "{:>4} {:>4} {:>4} {:>4} {:>6} {:>5} {:>6} {:>6} | {:>6} {:>6} {:>6}",
            row.n,
            row.ell,
            c.group.n1,
            c.group.p1,
            c.group.elem_bits,
            c.group.depth,
            c.group.openings,
            c.group.c_u_bits,
            row.r,
            row.c_u,
            row.c_t
        );
    }
    println!("\n=== Per-precision comm cost (q-level aggregation, per vote coordinate) ===");
    println!(
        "{:>4} {:>4} {:>5} {:>6} {:>6} {:>6} {:>8} {:>10} {:>12}",
        "n1", "q", "p1", "logp", "depth", "R", "C_u", "uplink/bit", "downlink/bit"
    );
    for n1 in [3usize, 4] {
        for row in cost::precision_costs(n1, policy, false) {
            println!(
                "{:>4} {:>4} {:>5} {:>6} {:>6} {:>6} {:>8} {:>10} {:>12}",
                n1,
                row.q,
                row.group.p1,
                row.group.elem_bits,
                row.group.depth,
                row.group.openings,
                row.group.c_u_bits,
                row.uplink_wire_bits,
                row.downlink_bits
            );
        }
    }
    Ok(())
}

fn cmd_fig6() -> Result<(), String> {
    println!("=== Fig. 6a: per-user masked uploads (R) — flat vs optimal subgrouping ===");
    println!("{:>4} {:>10} {:>12}", "n", "flat R", "subgroup R");
    for n in [12usize, 16, 20, 24, 28, 30, 36, 40, 50, 60, 70, 80, 90, 100] {
        let flat = cost::config_cost(n, 1, TiePolicy::OneBit, false);
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        println!("{:>4} {:>10} {:>12}", n, flat.group.openings, best.group.openings);
    }
    println!("\n=== Fig. 6b: latency (serial Beaver subrounds) ===");
    println!("{:>4} {:>10} {:>12}", "n", "flat", "subgroup");
    for n in [12usize, 16, 20, 24, 28, 30, 36, 40, 50, 60, 70, 80, 90, 100] {
        let flat = cost::config_cost(n, 1, TiePolicy::OneBit, false);
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        println!("{:>4} {:>10} {:>12}", n, flat.group.depth, best.group.depth);
    }
    Ok(())
}

fn cmd_security(args: &Args) -> Result<(), String> {
    args.check_known(&["n", "ell", "d"])?;
    let n = args.get_usize("n", 24)?;
    let ell = args.get_usize("ell", 8)?;
    let d = args.get_usize("d", 7850)?;
    if n % ell != 0 {
        return Err(format!("ℓ = {ell} must divide n = {n}"));
    }
    let n1 = n / ell;
    println!(
        "Hi-SAFE leakage profile (Theorem 2 / Remark 4), n={n}, ℓ={ell}, n₁={n1}, d={d}:"
    );
    println!(
        "  server learns: {ell} subgroup votes s_j ∈ {{−1,0,+1}}^{d} and the global vote"
    );
    println!(
        "  per-coordinate full-disclosure probability: 2^{}",
        -((n1 as i64) - 1)
    );
    println!(
        "  model-level full-disclosure probability: log2 = {:.0}",
        security::residual_leakage_log2(n1, d)
    );
    println!(
        "  flat baseline (ℓ=1): per-coordinate 2^{}",
        -((n as i64) - 1)
    );
    // live uniformity check on the real protocol
    use hisafe::util::rng::Rng;
    let mut transcripts = Vec::new();
    let mut rng = hisafe::util::rng::Xoshiro256pp::seed_from_u64(9);
    for run in 0..800u64 {
        let signs: Vec<Vec<i8>> = (0..n1).map(|_| vec![rng.gen_sign()]).collect();
        transcripts.push(
            hisafe::mpc::secure_group_vote(&signs, TiePolicy::OneBit, false, run).transcript,
        );
    }
    let fp = hisafe::field::field_for_group(n1);
    let counts = security::histogram_openings(fp, &transcripts);
    let chi2 = security::chi_square_uniform(&counts);
    let thr = security::chi2_threshold(counts.len() - 1);
    println!(
        "  live masked-opening uniformity over {} runs: chi2 = {:.1} (99.9% threshold {:.1}) → {}",
        transcripts.len(),
        chi2,
        thr,
        if chi2 < thr { "UNIFORM ✓" } else { "NON-UNIFORM ✗" }
    );
    Ok(())
}

/// One `sweep` tenant: `NxL[xD][@W][@qQ]` — `n` users in `ℓ` subgroups
/// voting over `d` coordinates (default d = 4096) with weighted
/// round-robin dealing weight `W` (default 1) at quantization precision
/// `Q` (default `default_q`; the `--precision` flag), e.g.
/// `24x8x2048@3@q4`. The `@` suffixes compose in any order: a token
/// starting with `q` is a precision, a bare number is a weight.
fn parse_tenant(spec: &str, default_q: u8) -> Result<(HiSafeConfig, usize, u32), String> {
    let mut at_parts = spec.split('@');
    let shape = at_parts.next().expect("split yields at least one token");
    let mut weight: u32 = 1;
    let mut precision: u8 = default_q;
    for tok in at_parts {
        if let Some(qs) = tok.strip_prefix('q') {
            let q: u8 = qs.parse().map_err(|_| {
                format!("tenant '{spec}': precision '@{tok}' must be @q2|@q4|@q8|@q16")
            })?;
            hisafe::quant::check_precision(q)
                .map_err(|e| format!("tenant '{spec}': {e}"))?;
            precision = q;
        } else {
            weight = tok.parse().map_err(|_| {
                format!("tenant '{spec}': weight '{tok}' must be a positive integer")
            })?;
            if weight == 0 {
                return Err(format!("tenant '{spec}': weight must be ≥ 1"));
            }
        }
    }
    let parts: Vec<&str> = shape.split('x').collect();
    if parts.len() != 2 && parts.len() != 3 {
        return Err(format!(
            "tenant '{spec}' must be NxL[xD][@W][@qQ], e.g. 24x8x2048@3@q4"
        ));
    }
    let num = |s: &str, what: &str| -> Result<usize, String> {
        s.parse::<usize>()
            .map_err(|_| format!("tenant '{spec}': {what} '{s}' must be a positive integer"))
    };
    let n = num(parts[0], "n")?;
    let ell = num(parts[1], "ell")?;
    let d = if parts.len() == 3 { num(parts[2], "d")? } else { 4096 };
    if n == 0 || ell == 0 || d == 0 {
        return Err(format!("tenant '{spec}': n, ell, d must all be ≥ 1"));
    }
    if n % ell != 0 {
        return Err(format!("tenant '{spec}': ℓ = {ell} must divide n = {n}"));
    }
    Ok((
        HiSafeConfig::hierarchical(n, ell, TiePolicy::OneBit).with_precision(precision),
        d,
        weight,
    ))
}

/// The sweep's tenant row label; q = 2 keeps the legacy `nN_lL_dD` form.
fn tenant_label(cfg: &HiSafeConfig, d: usize) -> String {
    if cfg.precision == 2 {
        format!("n{}_l{}_d{}", cfg.n, cfg.ell, d)
    } else {
        format!("n{}_l{}_d{}_q{}", cfg.n, cfg.ell, d, cfg.precision)
    }
}

/// Parse + validate the sweep's global `--precision Q` default (applied
/// to every tenant without an explicit `@qQ` suffix).
fn parse_precision(args: &Args) -> Result<u8, String> {
    let q = args.get_usize("precision", 2)?;
    let q = u8::try_from(q).map_err(|_| format!("--precision {q} out of range"))?;
    hisafe::quant::check_precision(q)?;
    Ok(q)
}

/// Draw one q-level vote coordinate: the legacy ±1 stream at `q = 2`
/// (so plain sweeps stay bit-identical to pre-quantization builds), a
/// uniform **odd** midrise level in `[−(q−1), q−1]` otherwise (`q` is a
/// power of two, so the modulus draw is unbiased).
fn gen_level(rng: &mut hisafe::util::rng::Xoshiro256pp, q: u8) -> i8 {
    use hisafe::util::rng::Rng;
    if q == 2 {
        rng.gen_sign()
    } else {
        let idx = (rng.next_u64() % q as u64) as i64;
        (2 * idx - (q as i64 - 1)) as i8
    }
}

/// Parse + validate `--churn P` (a probability; 0 disables churn).
fn parse_churn(args: &Args) -> Result<f64, String> {
    let churn = args.get_f64("churn", 0.0)?;
    if !(0.0..1.0).contains(&churn) {
        return Err(format!("--churn must be a probability in [0, 1), got {churn}"));
    }
    Ok(churn)
}

/// One per-round presence draw: each of `n` users independently answers
/// with probability `1 − churn` (53-bit mantissa uniform draw, same
/// sampling the trainer uses).
fn sample_mask(rng: &mut hisafe::util::rng::Xoshiro256pp, n: usize, churn: f64) -> Vec<bool> {
    use hisafe::util::rng::Rng;
    (0..n)
        .map(|_| {
            let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            u >= churn
        })
        .collect()
}

/// Run one deterministic chaos schedule (see [`hisafe::service::faults`])
/// against a real in-process cluster — two serve hosts behind a
/// balancer on loopback — and print its report. The seed the chaos test
/// suite (`cargo test --test chaos_props`) prints on failure replays
/// the identical schedule here: same tenants, same signs, same faults
/// at the same rounds.
fn cmd_sweep_chaos(args: &Args) -> Result<(), String> {
    if args.has("remote") {
        return Err("--chaos-seed runs its own in-process cluster; drop --remote".into());
    }
    let seed = args.get_u64("chaos-seed", 0)?;
    let plan = hisafe::service::faults::FaultPlan::from_seed(seed);
    println!(
        "# chaos seed {seed}: {} tenants, {} rounds, {} scheduled fault(s)",
        plan.tenants.len(),
        plan.rounds,
        plan.schedule.len()
    );
    for (round, fault) in &plan.schedule {
        println!("#   round {round}: {fault:?}");
    }
    // `run_schedule` asserts the anchor invariants as it goes and
    // panics with the offending context on any violation — so reaching
    // the report line IS the verdict.
    let report = hisafe::service::faults::run_schedule(seed);
    println!(
        "chaos seed {}: OK — {} vote(s) bit-identical to the reference, {} typed churn \
         abort(s), tenant precisions {:?}, faults applied: {:?}",
        report.seed, report.votes_checked, report.typed_aborts, report.precisions, report.faults
    );
    Ok(())
}

/// Mixed-tenant workload on one shared scheduler: every tenant is an
/// `AggSession` with its own `(cfg, d)` shape and QoS policy, rounds
/// interleave round-robin, and we report per-tenant round latency,
/// measured communication, and admission counters (throttles, dealing
/// share) — the heavy-traffic shape of the ROADMAP, observable from the
/// command line.
fn cmd_sweep(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "tenants", "rounds", "threads", "seed", "out", "rps", "tps", "queue-depth",
        "churn", "remote", "codec", "stop-server", "chaos-seed", "verbose", "threaded", "jax",
        "precision",
    ])?;
    if args.has("chaos-seed") {
        return cmd_sweep_chaos(args);
    }
    if args.has("remote") {
        return cmd_sweep_remote(args);
    }
    if args.has("codec") {
        return Err("--codec applies to --remote sweeps; a local sweep has no wire".into());
    }
    let rounds = args.get_usize("rounds", 5)?;
    if rounds == 0 {
        return Err("--rounds must be ≥ 1".into());
    }
    let base_seed = args.get_u64("seed", 42)?;
    let default_q = parse_precision(args)?;
    let tenant_specs = args.get_or("tenants", "24x8x2048,12x4x4096,6x2x8192");
    let shapes: Vec<(HiSafeConfig, usize, u32)> = tenant_specs
        .split(',')
        .map(|s| parse_tenant(s.trim(), default_q))
        .collect::<Result<_, _>>()?;
    // Global QoS knobs (0 = unlimited), applied to every tenant; the
    // per-tenant `@W` weight suffix sets the dealing share.
    let rps = args.get_f64("rps", 0.0)?;
    let tps = args.get_f64("tps", 0.0)?;
    let queue_depth = args.get_usize("queue-depth", 0)?;
    let churn = parse_churn(args)?;
    let threads = args.get_usize("threads", 0)?;
    let sched = if threads == 0 {
        AggScheduler::new()
    } else {
        AggScheduler::with_threads(threads)
    };
    println!(
        "# sweep: {} tenants on ONE scheduler — {} span workers + {} dealer thread(s) total{}",
        shapes.len(),
        sched.worker_threads(),
        sched.dealer_threads(),
        if churn > 0.0 { format!(", churn p = {churn}") } else { String::new() }
    );

    struct TenantRun {
        label: String,
        cfg: HiSafeConfig,
        d: usize,
        weight: u32,
        session: hisafe::engine::AggSession,
        rng: hisafe::util::rng::Xoshiro256pp,
        churn_rng: hisafe::util::rng::Xoshiro256pp,
        latencies_ms: Vec<f64>,
        throttle_wait_ms: f64,
        comm_last: Option<CommStats>,
        comm_total: CommStats,
        /// Survivor count per round (== n for every round when churn is
        /// off). Aborted rounds are listed too, so the vector always has
        /// one entry per round.
        survivors_per_round: Vec<usize>,
        aborted_rounds: u64,
        completed_rounds: u64,
        audited: bool,
    }

    let mut tenants: Vec<TenantRun> = Vec::with_capacity(shapes.len());
    for (i, &(cfg, d, weight)) in shapes.iter().enumerate() {
        let mut qos = QosPolicy::unlimited().with_weight(weight);
        if rps > 0.0 {
            qos = qos.with_rounds_per_sec(rps);
        }
        if tps > 0.0 {
            qos = qos.with_triples_per_sec(tps);
        }
        if queue_depth > 0 {
            qos = qos.with_queue_depth(queue_depth);
        }
        let session = sched
            .try_session(cfg, d, base_seed.wrapping_add(i as u64), qos)
            .map_err(|e| format!("tenant {i} not admitted: {e}"))?;
        tenants.push(TenantRun {
            label: tenant_label(&cfg, d),
            cfg,
            d,
            weight,
            session,
            rng: hisafe::util::rng::Xoshiro256pp::seed_from_u64(base_seed ^ ((i as u64) << 8)),
            churn_rng: hisafe::util::rng::Xoshiro256pp::seed_from_u64(
                base_seed ^ ((i as u64) << 8) ^ 0xc4021,
            ),
            latencies_ms: Vec::with_capacity(rounds),
            throttle_wait_ms: 0.0,
            comm_last: None,
            comm_total: CommStats::default(),
            survivors_per_round: Vec::with_capacity(rounds),
            aborted_rounds: 0,
            completed_rounds: 0,
            audited: false,
        });
    }

    for _round in 0..rounds {
        for t in tenants.iter_mut() {
            let q = t.cfg.precision;
            let signs: Vec<Vec<i8>> = (0..t.cfg.n)
                .map(|_| (0..t.d).map(|_| gen_level(&mut t.rng, q)).collect())
                .collect();
            // Per-round churn draw from a dedicated stream (the sign
            // stream is untouched, so --churn 0 sweeps are bit-identical
            // to pre-churn sweeps).
            let mask = if churn > 0.0 {
                sample_mask(&mut t.churn_rng, t.cfg.n, churn)
            } else {
                vec![true; t.cfg.n]
            };
            let survivors = mask.iter().filter(|&&p| p).count();
            t.survivors_per_round.push(survivors);
            // QoS-checked admission with blocking retry: the sweep runs
            // every round, so throttle denials become measured waits —
            // reported as throttle_wait_ms, and kept OUT of the round
            // latency columns (the slept time is subtracted, so
            // latencies_ms measures the admitted round only). A churned
            // round takes the threshold path over its survivors; a
            // below-threshold mask is a typed abort (counted, not
            // retried, never a panic).
            let t0 = std::time::Instant::now();
            let out = if survivors == t.cfg.n {
                let (out, _denials, waited) = t.session.run_round_admitted(&signs);
                t.throttle_wait_ms += waited.as_secs_f64() * 1e3;
                t.latencies_ms
                    .push(t0.elapsed().saturating_sub(waited).as_secs_f64() * 1e3);
                out
            } else {
                let pset = ParticipantSet::from_mask(mask);
                match t.session.run_round_admitted_present(&signs, &pset) {
                    Ok((out, _denials, waited)) => {
                        t.throttle_wait_ms += waited.as_secs_f64() * 1e3;
                        t.latencies_ms
                            .push(t0.elapsed().saturating_sub(waited).as_secs_f64() * 1e3);
                        // Audit churned rounds against the plaintext vote
                        // over the same survivor set.
                        if !t.audited {
                            assert_eq!(
                                out.global_vote,
                                plain_quant_aggregate_present(&signs, &pset, t.cfg),
                                "tenant {} produced a wrong churned vote",
                                t.label
                            );
                        }
                        out
                    }
                    Err(AdmissionError::ChurnBelowThreshold { .. }) => {
                        t.aborted_rounds += 1;
                        continue;
                    }
                    Err(e) => {
                        panic!("tenant {} round failed: {e}", t.label)
                    }
                }
            };
            if !t.audited && survivors == t.cfg.n {
                // One correctness audit per tenant: scheduled votes must
                // equal the plaintext hierarchical majority vote.
                assert_eq!(
                    out.global_vote,
                    plain_quant_aggregate(&signs, t.cfg),
                    "tenant {} produced a wrong vote",
                    t.label
                );
            }
            t.audited = true;
            t.completed_rounds += 1;
            t.comm_total.merge(&out.stats);
            t.comm_last = Some(out.stats);
        }
    }

    println!(
        "\n{:<16} {:>3} {:>6} {:>10} {:>10} {:>10} {:>9} {:>6} {:>12} {:>10}",
        "tenant", "w", "rounds", "mean ms", "min ms", "max ms", "throttle", "dealt",
        "C_u bits/rd", "mults/rd"
    );
    let mut report = Json::obj();
    let mut tenant_objs: Vec<Json> = Vec::new();
    for t in &tenants {
        // Under heavy churn a tenant can abort every round: latency and
        // comm columns then report zeros rather than NaN/∞ (which would
        // also not be valid JSON).
        let ran = !t.latencies_ms.is_empty();
        let mean = if ran {
            t.latencies_ms.iter().sum::<f64>() / t.latencies_ms.len() as f64
        } else {
            0.0
        };
        let min = if ran {
            t.latencies_ms.iter().cloned().fold(f64::INFINITY, f64::min)
        } else {
            0.0
        };
        let max = t.latencies_ms.iter().cloned().fold(0.0f64, f64::max);
        let comm = t.comm_last.clone().unwrap_or_default();
        let adm = t.session.admission_stats();
        println!(
            "{:<16} {:>3} {:>6} {:>10.2} {:>10.2} {:>10.2} {:>9} {:>6} {:>12} {:>10}",
            t.label,
            t.weight,
            t.latencies_ms.len(),
            mean,
            min,
            max,
            adm.throttled,
            t.session.dealt_rounds(),
            comm.c_u_bits(),
            comm.mults
        );
        if churn > 0.0 {
            println!(
                "  churn: {} completed, {} aborted (below threshold), survivors/round {:?}",
                t.completed_rounds, t.aborted_rounds, t.survivors_per_round
            );
        }
        let mut qos_obj = Json::obj();
        qos_obj.set("weight", t.weight);
        if rps > 0.0 {
            qos_obj.set("rounds_per_sec", rps);
        }
        if tps > 0.0 {
            qos_obj.set("triples_per_sec", tps);
        }
        if queue_depth > 0 {
            qos_obj.set("queue_depth", queue_depth);
        }
        let mut o = Json::obj();
        o.set("tenant", t.label.clone())
            .set("n", t.cfg.n)
            .set("ell", t.cfg.ell)
            .set("d", t.d)
            .set("precision", t.cfg.precision as u32)
            .set("rounds", t.latencies_ms.len())
            .set("mean_ms", mean)
            .set("min_ms", min)
            .set("max_ms", max)
            .set("throttle_wait_ms", t.throttle_wait_ms)
            .set("dealt_rounds", t.session.dealt_rounds())
            .set("qos", qos_obj)
            .set("admission", adm.to_json())
            .set("comm_per_round", comm.to_json())
            .set("comm_total", t.comm_total.to_json())
            .set("survivors_per_round", t.survivors_per_round.clone())
            .set("completed_rounds", t.completed_rounds)
            .set("aborted_rounds", t.aborted_rounds)
            // Modeled packed-wire volume for this shape (a local sweep
            // has no socket to measure): all-n uplink + broadcast
            // downlink bits per round at this tenant's precision.
            .set(
                "uplink_wire_bits_per_round",
                hisafe::quant::uplink_bits(t.cfg.precision) as u64
                    * t.cfg.n as u64
                    * t.d as u64,
            )
            .set(
                "downlink_bits_per_round",
                hisafe::quant::downlink_bits(t.cfg.precision, t.cfg.inter) as u64
                    * t.d as u64,
            );
        tenant_objs.push(o);
    }
    report
        .set("worker_threads", sched.worker_threads())
        .set("dealer_threads", sched.dealer_threads())
        .set("churn", churn)
        .set("tenants", tenant_objs);

    let out_dir = args.get_or("out", "runs");
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let path = format!("{out_dir}/sweep.json");
    std::fs::write(&path, report.to_string_pretty()).map_err(|e| e.to_string())?;
    println!("\nwrote {path}");
    Ok(())
}

/// The `sweep` workload driven across the wire: every tenant is a
/// session on a remote `hisafe serve` frontend, rounds submit over
/// loopback TCP with client-side throttle retries, and the report adds
/// the frontend's shard layout. Vote correctness is still audited
/// client-side against the plaintext reference — the wire cannot change
/// votes, only where they are computed.
fn cmd_sweep_remote(args: &Args) -> Result<(), String> {
    let addr = args.get("remote").expect("checked by caller").to_string();
    let rounds = args.get_usize("rounds", 5)?;
    if rounds == 0 {
        return Err("--rounds must be ≥ 1".into());
    }
    let base_seed = args.get_u64("seed", 42)?;
    let default_q = parse_precision(args)?;
    let tenant_specs = args.get_or("tenants", "24x8x2048,12x4x4096,6x2x8192");
    let shapes: Vec<(HiSafeConfig, usize, u32)> = tenant_specs
        .split(',')
        .map(|s| parse_tenant(s.trim(), default_q))
        .collect::<Result<_, _>>()?;
    let rps = args.get_f64("rps", 0.0)?;
    let tps = args.get_f64("tps", 0.0)?;
    let queue_depth = args.get_usize("queue-depth", 0)?;
    let churn = parse_churn(args)?;
    if args.has("threads") {
        return Err("--threads is a server-side knob; pass it to `hisafe serve`".into());
    }

    // Default json: a plain remote sweep is byte-identical on the wire
    // to the pre-binary client; --codec binary opts into the v2 framing
    // (negotiated per connection — an old/JSON-policy server just never
    // acks, and the sweep runs on JSON with identical votes).
    let want = Codec::from_name(args.get_or("codec", "json"))
        .ok_or("--codec must be json|binary")?;
    let mut client = ServiceClient::connect_with_codec(&addr, want)
        .map_err(|e| format!("connect to {addr}: {e}"))?;
    println!(
        "# remote sweep: {} tenants against {addr}, codec {} requested{}",
        shapes.len(),
        want.name(),
        if churn > 0.0 { format!(", churn p = {churn}") } else { String::new() }
    );

    struct RemoteTenant {
        label: String,
        cfg: HiSafeConfig,
        d: usize,
        weight: u32,
        sid: SessionId,
        rng: hisafe::util::rng::Xoshiro256pp,
        churn_rng: hisafe::util::rng::Xoshiro256pp,
        latencies_ms: Vec<f64>,
        throttle_wait_ms: f64,
        comm_last: Option<CommStats>,
        comm_total: CommStats,
        survivors_per_round: Vec<usize>,
        aborted_rounds: u64,
        completed_rounds: u64,
        /// Wire bytes (sent + received, headers included) this tenant's
        /// round submissions cost — the bandwidth column of the report.
        wire_bytes: u64,
        audited: bool,
    }

    let mut tenants: Vec<RemoteTenant> = Vec::with_capacity(shapes.len());
    for (i, &(cfg, d, weight)) in shapes.iter().enumerate() {
        let mut qos = QosPolicy::unlimited().with_weight(weight);
        if rps > 0.0 {
            qos = qos.with_rounds_per_sec(rps);
        }
        if tps > 0.0 {
            qos = qos.with_triples_per_sec(tps);
        }
        if queue_depth > 0 {
            qos = qos.with_queue_depth(queue_depth);
        }
        let sid = client
            .open_session(cfg, d, base_seed.wrapping_add(i as u64), qos)
            .map_err(|e| format!("tenant {i} not admitted: {e}"))?;
        tenants.push(RemoteTenant {
            label: tenant_label(&cfg, d),
            cfg,
            d,
            weight,
            sid,
            rng: hisafe::util::rng::Xoshiro256pp::seed_from_u64(base_seed ^ ((i as u64) << 8)),
            churn_rng: hisafe::util::rng::Xoshiro256pp::seed_from_u64(
                base_seed ^ ((i as u64) << 8) ^ 0xc4021,
            ),
            latencies_ms: Vec::with_capacity(rounds),
            throttle_wait_ms: 0.0,
            comm_last: None,
            comm_total: CommStats::default(),
            survivors_per_round: Vec::with_capacity(rounds),
            aborted_rounds: 0,
            completed_rounds: 0,
            wire_bytes: 0,
            audited: false,
        });
    }

    for round in 0..rounds {
        for t in tenants.iter_mut() {
            let q = t.cfg.precision;
            let signs: Vec<Vec<i8>> = (0..t.cfg.n)
                .map(|_| (0..t.d).map(|_| gen_level(&mut t.rng, q)).collect())
                .collect();
            // Same dedicated churn stream as the local sweep — identical
            // seeds draw identical masks, so a remote sweep reproduces
            // the local survivor sets exactly.
            let mask = if churn > 0.0 {
                sample_mask(&mut t.churn_rng, t.cfg.n, churn)
            } else {
                vec![true; t.cfg.n]
            };
            let survivors = mask.iter().filter(|&&p| p).count();
            t.survivors_per_round.push(survivors);
            let wire0 = client.bytes_sent() + client.bytes_received();
            let t0 = std::time::Instant::now();
            let reply = if survivors == t.cfg.n {
                let (reply, _denials, waited) = client
                    .run_round_admitted(t.sid, &signs)
                    .map_err(|e| format!("tenant {} round {round}: {e}", t.label))?;
                t.throttle_wait_ms += waited.as_secs_f64() * 1e3;
                t.latencies_ms
                    .push(t0.elapsed().saturating_sub(waited).as_secs_f64() * 1e3);
                reply
            } else {
                match client.run_round_admitted_present(t.sid, &signs, Some(&mask)) {
                    Ok((reply, _denials, waited)) => {
                        t.throttle_wait_ms += waited.as_secs_f64() * 1e3;
                        t.latencies_ms
                            .push(t0.elapsed().saturating_sub(waited).as_secs_f64() * 1e3);
                        if !t.audited {
                            assert_eq!(
                                reply.global_vote,
                                plain_quant_aggregate_present(
                                    &signs,
                                    &ParticipantSet::from_mask(mask),
                                    t.cfg,
                                ),
                                "tenant {} produced a wrong churned vote over the wire",
                                t.label
                            );
                        }
                        reply
                    }
                    Err(hisafe::service::Error::Admission(
                        AdmissionError::ChurnBelowThreshold { .. },
                    )) => {
                        t.aborted_rounds += 1;
                        t.wire_bytes += client.bytes_sent() + client.bytes_received() - wire0;
                        continue;
                    }
                    Err(e) => {
                        return Err(format!("tenant {} round {round}: {e}", t.label));
                    }
                }
            };
            t.wire_bytes += client.bytes_sent() + client.bytes_received() - wire0;
            if !t.audited && survivors == t.cfg.n {
                assert_eq!(
                    reply.global_vote,
                    plain_quant_aggregate(&signs, t.cfg),
                    "tenant {} produced a wrong vote over the wire",
                    t.label
                );
            }
            t.audited = true;
            t.completed_rounds += 1;
            t.comm_total.merge(&reply.stats);
            t.comm_last = Some(reply.stats);
        }
    }

    println!(
        "\n{:<16} {:>3} {:>5} {:>6} {:>10} {:>10} {:>10} {:>9} {:>6} {:>12} {:>10}",
        "tenant", "w", "shard", "rounds", "mean ms", "min ms", "max ms", "throttle", "dealt",
        "C_u bits/rd", "mults/rd"
    );
    let mut report = Json::obj();
    let mut tenant_objs: Vec<Json> = Vec::new();
    for t in &tenants {
        let ran = !t.latencies_ms.is_empty();
        let mean = if ran {
            t.latencies_ms.iter().sum::<f64>() / t.latencies_ms.len() as f64
        } else {
            0.0
        };
        let min = if ran {
            t.latencies_ms.iter().cloned().fold(f64::INFINITY, f64::min)
        } else {
            0.0
        };
        let max = t.latencies_ms.iter().cloned().fold(0.0f64, f64::max);
        let comm = t.comm_last.clone().unwrap_or_default();
        let stats = client
            .stats(Some(t.sid))
            .map_err(|e| format!("stats for tenant {}: {e}", t.label))?;
        let shard = stats.shard.expect("session stats carry a shard");
        println!(
            "{:<16} {:>3} {:>5} {:>6} {:>10.2} {:>10.2} {:>10.2} {:>9} {:>6} {:>12} {:>10}",
            t.label,
            t.weight,
            shard,
            t.latencies_ms.len(),
            mean,
            min,
            max,
            stats.admission.throttled,
            stats.dealt_rounds,
            comm.c_u_bits(),
            comm.mults
        );
        if churn > 0.0 {
            println!(
                "  churn: {} completed, {} aborted (below threshold), survivors/round {:?}",
                t.completed_rounds, t.aborted_rounds, t.survivors_per_round
            );
        }
        let mut qos_obj = Json::obj();
        qos_obj.set("weight", t.weight);
        if rps > 0.0 {
            qos_obj.set("rounds_per_sec", rps);
        }
        if tps > 0.0 {
            qos_obj.set("triples_per_sec", tps);
        }
        if queue_depth > 0 {
            qos_obj.set("queue_depth", queue_depth);
        }
        let mut o = Json::obj();
        o.set("tenant", t.label.clone())
            .set("n", t.cfg.n)
            .set("ell", t.cfg.ell)
            .set("d", t.d)
            .set("precision", t.cfg.precision as u32)
            .set("shard", shard)
            .set("rounds", t.latencies_ms.len())
            .set("mean_ms", mean)
            .set("min_ms", min)
            .set("max_ms", max)
            .set("throttle_wait_ms", t.throttle_wait_ms)
            .set("dealt_rounds", stats.dealt_rounds)
            .set("qos", qos_obj)
            .set("admission", stats.admission.to_json())
            .set("comm_per_round", comm.to_json())
            .set("comm_total", t.comm_total.to_json())
            .set("survivors_per_round", t.survivors_per_round.clone())
            .set("completed_rounds", t.completed_rounds)
            .set("aborted_rounds", t.aborted_rounds)
            .set("wire_bytes_total", t.wire_bytes)
            .set(
                "wire_bytes_per_round",
                if t.completed_rounds > 0 { t.wire_bytes / t.completed_rounds } else { 0 },
            );
        tenant_objs.push(o);
    }
    let round_bytes: u64 = tenants.iter().map(|t| t.wire_bytes).sum();
    let round_count: u64 = tenants.iter().map(|t| t.completed_rounds).sum();
    println!(
        "# wire: codec {} in effect — {} bytes sent, {} bytes received \
         ({} bytes/round over {} completed rounds)",
        client.codec().name(),
        client.bytes_sent(),
        client.bytes_received(),
        if round_count > 0 { round_bytes / round_count } else { 0 },
        round_count
    );
    // Frontend-wide layout before the sessions close.
    let fe = client.stats(None).map_err(|e| format!("frontend stats: {e}"))?;
    report
        .set("remote", addr.clone())
        .set("protocol_version", PROTOCOL_VERSION)
        .set("codec", client.codec().name())
        .set("bytes_sent", client.bytes_sent())
        .set("bytes_received", client.bytes_received())
        .set("shard_tenants", fe.shard_tenants.unwrap_or_default())
        .set("churn", churn)
        .set("tenants", tenant_objs);

    for t in &tenants {
        client
            .close_session(t.sid)
            .map_err(|e| format!("close tenant {}: {e}", t.label))?;
    }

    let out_dir = args.get_or("out", "runs");
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let path = format!("{out_dir}/sweep.json");
    std::fs::write(&path, report.to_string_pretty()).map_err(|e| e.to_string())?;
    println!("\nwrote {path}");

    if args.has("stop-server") {
        client.shutdown().map_err(|e| format!("shutdown: {e}"))?;
        println!("server at {addr} acknowledged shutdown");
    }
    Ok(())
}

/// `hisafe serve` — the sharded aggregation service: an [`AggFrontend`]
/// over `--shards` scheduler shards on TCP, speaking newline-delimited
/// JSON per connection and negotiating up to the v2 binary framing when
/// a client asks (unless `--codec json`). Blocks until a client sends
/// the protocol's Shutdown request (e.g. `hisafe sweep --remote ADDR
/// --stop-server`).
fn cmd_serve(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "addr", "shards", "threads", "max-tenants", "workers", "codec", "verbose", "threaded",
        "jax",
    ])?;
    let addr = args.get_or("addr", "127.0.0.1:7433");
    let shards = args.get_usize("shards", 2)?;
    if shards == 0 {
        return Err("--shards must be ≥ 1".into());
    }
    let threads = args.get_usize("threads", 2)?;
    if threads == 0 {
        return Err("--threads must be ≥ 1 (span workers per shard)".into());
    }
    let workers = args.get_usize("workers", 4)?;
    if workers == 0 {
        return Err("--workers must be ≥ 1 (connection workers)".into());
    }
    let max_tenants = args.get_usize("max-tenants", 0)?;
    // "binary" means binary-*capable*: JSON clients are always served;
    // "json" refuses to ack binary asks (debugging, mixed clusters).
    let codec = Codec::from_name(args.get_or("codec", "binary"))
        .ok_or("--codec must be json|binary")?;
    let frontend = if max_tenants > 0 {
        AggFrontend::with_shard_capacity(shards, threads, max_tenants)
    } else {
        AggFrontend::new(shards, threads)
    };
    let server = ServiceServer::bind_with_workers(addr, frontend, workers)
        .map_err(|e| format!("bind {addr}: {e}"))?
        .with_codec(codec);
    let local = server.local_addr().map_err(|e| e.to_string())?;
    println!(
        "hisafe service listening on {local} — {shards} shard(s) x {threads} engine worker(s), \
         {workers} connection worker(s), protocol v{PROTOCOL_VERSION}, codec {}{}",
        codec.name(),
        if max_tenants > 0 {
            format!(", max {max_tenants} tenants/shard")
        } else {
            String::new()
        }
    );
    println!("stop with: hisafe sweep --remote {local} --stop-server");
    server.serve().map_err(|e| e.to_string())?;
    println!("service stopped cleanly");
    Ok(())
}

/// `hisafe balance` — the fail-over balancer: fronts several `hisafe
/// serve` hosts behind one address speaking the identical wire
/// protocol. Sessions are placed by rendezvous hashing, health-checked
/// every `--health-ms`, and transparently restored (bit-identically,
/// via session snapshots) onto a surviving host when their host dies.
/// Blocks until a client sends Shutdown, which also winds down every
/// live backend host.
fn cmd_balance(args: &Args) -> Result<(), String> {
    args.check_known(&["addr", "hosts", "health-ms", "codec", "verbose", "threaded", "jax"])?;
    let addr = args.get_or("addr", "127.0.0.1:7432");
    let hosts: Vec<String> = args
        .get("hosts")
        .ok_or("balance needs --hosts HOST:PORT[,HOST:PORT...] (running `hisafe serve` hosts)")?
        .split(',')
        .map(|h| h.trim().to_string())
        .filter(|h| !h.is_empty())
        .collect();
    if hosts.is_empty() {
        return Err("--hosts must list at least one serve host".into());
    }
    let health_ms = args.get_u64("health-ms", 250)?;
    if health_ms == 0 {
        return Err("--health-ms must be ≥ 1".into());
    }
    let codec = Codec::from_name(args.get_or("codec", "binary"))
        .ok_or("--codec must be json|binary")?;
    let bal = Balancer::bind(addr, &hosts, std::time::Duration::from_millis(health_ms))
        .map_err(|e| format!("bind {addr}: {e}"))?
        .with_codec(codec);
    let local = bal.local_addr().map_err(|e| e.to_string())?;
    println!(
        "hisafe balancer listening on {local} — {} backend host(s) [{}], health every {health_ms}ms, \
         protocol v{PROTOCOL_VERSION}, codec {}",
        hosts.len(),
        hosts.join(", "),
        codec.name()
    );
    println!("stop the whole cluster with: hisafe sweep --remote {local} --stop-server");
    bal.serve().map_err(|e| e.to_string())?;
    println!("balancer stopped cleanly");
    Ok(())
}

fn cmd_demo() -> Result<(), String> {
    // The Appendix-A example; the full annotated walkthrough lives in
    // examples/secure_vote_demo.rs.
    let signs = vec![vec![1i8], vec![-1], vec![1]];
    let out = hisafe::mpc::secure_group_vote(&signs, TiePolicy::OneBit, false, 42);
    println!(
        "Appendix A: users (+1, −1, +1) → F(x) = {} → vote {:+}",
        out.raw[0], out.votes[0]
    );
    println!(
        "subrounds: {}  per-user openings: {}  C_u: {} bits/coordinate",
        out.stats.subrounds,
        out.stats.uplink_elems_per_user,
        out.stats.c_u_bits()
    );
    println!("(run `cargo run --release --example secure_vote_demo` for the step-by-step trace)");
    Ok(())
}
