//! Beaver multiplication triples (Beaver, CRYPTO'91) — the offline phase
//! of Hi-SAFE's secure polynomial evaluation (Section III-B2, Table V).
//!
//! A triple is `(a, b, c)` with `c = a·b (mod p)`, additively shared among
//! the `n₁` users of a subgroup. One fresh triple is consumed per secure
//! multiplication; with masks `δ = x − a`, `ε = y − b` publicly opened,
//! each user can locally form its share of `x·y`.
//!
//! The paper treats triple generation as an offline MPC black box
//! ("generated via MPC", Table V measures it at <0.01 s). We implement a
//! **trusted-dealer simulation** ([`Dealer`]): a ChaCha20-seeded dealer
//! samples `a, b` uniformly and distributes additive shares. Lemma 2 only
//! requires that `a, b` be uniform and unknown to the corrupted coalition
//! (≥1 honest share suffices), which the dealer model preserves — see
//! DESIGN.md §Substitutions.

use crate::field::Fp;
use crate::sharing::share_vec;
use crate::util::rng::{ChaCha20Rng, Rng};

/// One party's share of one vector Beaver triple.
#[derive(Debug, Clone)]
pub struct TripleShare {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
}

impl TripleShare {
    pub fn dim(&self) -> usize {
        self.a.len()
    }
}

/// Offline-phase triple dealer.
pub struct Dealer {
    fp: Fp,
    rng: ChaCha20Rng,
    /// Number of vector triples generated (for the Table-V accounting).
    pub generated: usize,
    /// Reused secret-vector scratch (`a`, `b`, `c = a·b`). The secrets
    /// never leave the dealer — only their *shares* are returned, which
    /// must be owned per party anyway — so the triple loop allocates
    /// nothing but the shares it hands out. Scratch reuse is invisible to
    /// the ChaCha20 stream: `fill_field` consumes exactly the same draws
    /// whether the buffer is fresh or recycled.
    scratch: [Vec<u64>; 3],
}

impl Dealer {
    pub fn new(fp: Fp, seed: u64) -> Dealer {
        Dealer {
            fp,
            rng: ChaCha20Rng::seed_from_u64(seed),
            generated: 0,
            scratch: [Vec::new(), Vec::new(), Vec::new()],
        }
    }

    /// Generate one vector triple of dimension `d`, shared among
    /// `n_parties`. Returns one [`TripleShare`] per party.
    pub fn gen_triple(&mut self, d: usize, n_parties: usize) -> Vec<TripleShare> {
        let p = self.fp.modulus();
        let [a, b, c] = &mut self.scratch;
        a.resize(d, 0);
        b.resize(d, 0);
        c.resize(d, 0);
        self.rng.fill_field(p, a);
        self.rng.fill_field(p, b);
        self.fp.vec_mul_into(c, a, b);
        let sa = share_vec(self.fp, a, n_parties, &mut self.rng);
        let sb = share_vec(self.fp, b, n_parties, &mut self.rng);
        let sc = share_vec(self.fp, c, n_parties, &mut self.rng);
        self.generated += 1;
        sa.into_iter()
            .zip(sb)
            .zip(sc)
            .map(|((a, b), c)| TripleShare { a, b, c })
            .collect()
    }

    /// Generate the `n_mults` triples one subgroup needs for one round:
    /// `out[party][mult]`.
    pub fn gen_round(
        &mut self,
        d: usize,
        n_parties: usize,
        n_mults: usize,
    ) -> Vec<Vec<TripleShare>> {
        let mut per_party: Vec<Vec<TripleShare>> =
            (0..n_parties).map(|_| Vec::with_capacity(n_mults)).collect();
        for _ in 0..n_mults {
            for (pid, ts) in self.gen_triple(d, n_parties).into_iter().enumerate() {
                per_party[pid].push(ts);
            }
        }
        per_party
    }

    /// Field ops performed per `gen_round` call — `Θ(ℓ·d_sub·n₁²)` across
    /// all subgroups in the paper's Table V accounting (sharing each of
    /// 3 vectors to n parties dominates).
    pub fn round_cost_field_ops(d: usize, n_parties: usize, n_mults: usize) -> usize {
        n_mults * d * (3 * n_parties + 1)
    }
}

/// Per-party triple stash with consumption audit: the protocol must use
/// each triple exactly once (freshness is what makes openings uniform,
/// Lemma 2).
#[derive(Debug)]
pub struct TripleStore {
    triples: Vec<TripleShare>,
    next: usize,
}

impl TripleStore {
    pub fn new(triples: Vec<TripleShare>) -> TripleStore {
        TripleStore { triples, next: 0 }
    }

    /// Take the next fresh triple; panics if exhausted (protocol bug).
    pub fn take(&mut self) -> &TripleShare {
        let i = self.next;
        assert!(
            i < self.triples.len(),
            "TripleStore exhausted: {} triples, requested #{}",
            self.triples.len(),
            i + 1
        );
        self.next += 1;
        &self.triples[i]
    }

    /// Take `k` fresh triples at once — the round-batched consumption path
    /// of [`crate::engine::RoundEngine`]: one bounds check per round
    /// instead of one per multiplication, and the returned slice can be
    /// shared read-only across the engine's worker threads. Panics if the
    /// pool cannot cover the request (same freshness audit as [`take`]).
    ///
    /// (A by-index `get` once lived here for a subround-batching path
    /// that never materialized; it bypassed the consumption audit — the
    /// Lemma 2 freshness invariant — with an unchecked index, so it was
    /// removed rather than left as an unaudited back door.)
    ///
    /// [`take`]: TripleStore::take
    pub fn take_many(&mut self, k: usize) -> &[TripleShare] {
        assert!(
            self.next + k <= self.triples.len(),
            "TripleStore exhausted: {} triples, requested {}..{}",
            self.triples.len(),
            self.next + 1,
            self.next + k
        );
        let start = self.next;
        self.next += k;
        &self.triples[start..self.next]
    }

    /// Like [`take_many`] but transfers ownership of the `k` fresh
    /// triples — the pipelined engine hands one round's triples to its
    /// persistent `'static` span workers behind an `Arc`, which a
    /// borrowing take cannot do. Same freshness audit and panic behavior;
    /// previously-consumed (borrowed) triples stay counted by
    /// [`consumed`] until the next [`refill`] compacts them.
    ///
    /// [`take_many`]: TripleStore::take_many
    /// [`consumed`]: TripleStore::consumed
    /// [`refill`]: TripleStore::refill
    pub fn take_many_owned(&mut self, k: usize) -> Vec<TripleShare> {
        assert!(
            self.next + k <= self.triples.len(),
            "TripleStore exhausted: {} triples, requested {}..{}",
            self.triples.len(),
            self.next + 1,
            self.next + k
        );
        self.triples.drain(self.next..self.next + k).collect()
    }

    /// Add freshly dealt triples to the pool, dropping the consumed prefix
    /// first so a long-lived engine's memory stays bounded by
    /// `remaining + new` rather than growing with protocol lifetime.
    pub fn refill(&mut self, fresh: Vec<TripleShare>) {
        if self.next > 0 {
            self.triples.drain(..self.next);
            self.next = 0;
        }
        self.triples.extend(fresh);
    }

    pub fn consumed(&self) -> usize {
        self.next
    }

    pub fn remaining(&self) -> usize {
        self.triples.len() - self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::next_prime;
    use crate::prop_assert_eq;
    use crate::sharing::reconstruct_vec;
    use crate::util::prop::forall;

    #[test]
    fn triples_satisfy_c_eq_ab() {
        forall("beaver c = a·b", 100, |g| {
            let p = g.prime(101);
            let fp = Fp::new(p);
            let d = g.usize_range(1, 32);
            let n = g.usize_range(2, 10);
            let mut dealer = Dealer::new(fp, g.u64());
            let shares = dealer.gen_triple(d, n);
            prop_assert_eq!(shares.len(), n);
            let a = reconstruct_vec(fp, &shares.iter().map(|t| t.a.clone()).collect::<Vec<_>>());
            let b = reconstruct_vec(fp, &shares.iter().map(|t| t.b.clone()).collect::<Vec<_>>());
            let c = reconstruct_vec(fp, &shares.iter().map(|t| t.c.clone()).collect::<Vec<_>>());
            prop_assert_eq!(c, fp.vec_mul(&a, &b));
            Ok(())
        });
    }

    #[test]
    fn gen_round_layout() {
        let fp = Fp::new(next_prime(6));
        let mut dealer = Dealer::new(fp, 42);
        let round = dealer.gen_round(8, 6, 5);
        assert_eq!(round.len(), 6); // parties
        for party in &round {
            assert_eq!(party.len(), 5); // mults
            for t in party {
                assert_eq!(t.dim(), 8);
            }
        }
        assert_eq!(dealer.generated, 5);
        // reconstruct mult #3 and check the invariant across the layout
        let a = reconstruct_vec(fp, &round.iter().map(|p| p[3].a.clone()).collect::<Vec<_>>());
        let b = reconstruct_vec(fp, &round.iter().map(|p| p[3].b.clone()).collect::<Vec<_>>());
        let c = reconstruct_vec(fp, &round.iter().map(|p| p[3].c.clone()).collect::<Vec<_>>());
        assert_eq!(c, fp.vec_mul(&a, &b));
    }

    #[test]
    fn store_audits_consumption() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 7);
        let mut shares = dealer.gen_round(4, 3, 2);
        let mut store = TripleStore::new(shares.remove(0));
        assert_eq!(store.remaining(), 2);
        store.take();
        store.take();
        assert_eq!(store.consumed(), 2);
        assert_eq!(store.remaining(), 0);
    }

    #[test]
    fn take_many_and_refill_preserve_freshness() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 9);
        let mut shares = dealer.gen_round(4, 3, 3);
        let party0 = shares.remove(0);
        let original_third = party0[2].clone();
        let mut store = TripleStore::new(party0);
        let first = store.take_many(2);
        assert_eq!(first.len(), 2);
        assert_eq!(store.remaining(), 1);
        // refill compacts the consumed prefix and appends fresh triples
        let mut more = dealer.gen_round(4, 3, 2);
        store.refill(more.remove(0));
        assert_eq!(store.consumed(), 0);
        assert_eq!(store.remaining(), 3);
        // the un-consumed triple survives the compaction, in order
        let next = store.take_many(1);
        assert_eq!(next[0].a, original_third.a);
        assert_eq!(next[0].c, original_third.c);
    }

    #[test]
    fn take_many_owned_transfers_fresh_triples_in_order() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 9);
        let mut shares = dealer.gen_round(4, 3, 3);
        let party0 = shares.remove(0);
        let expect_second = party0[1].clone();
        let mut store = TripleStore::new(party0);
        store.take(); // consume #1 via the borrowing path
        let owned = store.take_many_owned(2);
        assert_eq!(owned.len(), 2);
        // ownership transfer preserves stream order: #2 comes out first
        assert_eq!(owned[0].a, expect_second.a);
        assert_eq!(owned[0].c, expect_second.c);
        // audit intact: the borrowed prefix is still accounted, the
        // drained triples are gone for good
        assert_eq!(store.consumed(), 1);
        assert_eq!(store.remaining(), 0);
    }

    #[test]
    #[should_panic(expected = "TripleStore exhausted")]
    fn take_many_owned_panics_when_overdrawn() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 7);
        let mut shares = dealer.gen_round(4, 3, 2);
        let mut store = TripleStore::new(shares.remove(0));
        store.take_many_owned(3);
    }

    #[test]
    #[should_panic(expected = "TripleStore exhausted")]
    fn take_many_panics_when_overdrawn() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 7);
        let mut shares = dealer.gen_round(4, 3, 2);
        let mut store = TripleStore::new(shares.remove(0));
        store.take_many(3);
    }

    #[test]
    #[should_panic(expected = "TripleStore exhausted")]
    fn store_panics_on_reuse_beyond_budget() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 7);
        let mut shares = dealer.gen_round(4, 3, 1);
        let mut store = TripleStore::new(shares.remove(0));
        store.take();
        store.take(); // second take must panic: no triple reuse
    }
}
