//! # Hi-SAFE — Hierarchical Secure Aggregation for Lightweight Federated Learning
//!
//! A full-system reproduction of the Hi-SAFE paper (Joo, Hong, Lee, Shin, 2025):
//! cryptographically secure aggregation for sign-based federated learning
//! (SIGNSGD-MV), built on:
//!
//! * **Majority-vote polynomials over prime fields** derived from Fermat's
//!   Little Theorem ([`poly`]), so that the server learns *only* the majority
//!   vote, never any individual sign gradient or intermediate sum.
//! * **Secure polynomial evaluation** via additive secret sharing and Beaver
//!   triples ([`sharing`], [`beaver`], [`mpc`]).
//! * **Hierarchical subgrouping** ([`protocol`]) that keeps the multiplicative
//!   depth constant (≈2 subrounds) and per-user secure-multiplication cost
//!   bounded (≤6) independent of the total number of users `n`.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! ```text
//! L3  rust     — this crate: protocol engine, FL orchestration, cost model
//! L2  jax      — model fwd/bwd (python/compile/model.py), AOT-lowered to HLO
//! L1  pallas   — majority-vote polynomial + sign kernels (python/compile/kernels)
//! ```
//!
//! Python never runs on the request path: `make artifacts` lowers the L2/L1
//! computations once to `artifacts/*.hlo.txt`, and [`runtime`] loads and
//! executes them through the PJRT C API (`xla` crate).

// The architecture docs deliberately reference private plumbing
// ([`engine::pool`]'s `GroupPools`, the worker pool, …) because the
// determinism argument lives there; rustdoc cannot link to private items
// from public pages, and that is fine — the names still read as code.
// Genuinely broken links stay fatal: CI runs `cargo doc --no-deps` with
// `RUSTDOCFLAGS="-D warnings"`, which keeps `broken_intra_doc_links` (and
// every other rustdoc lint) as a hard gate.
#![allow(rustdoc::private_intra_doc_links)]

pub mod baselines;
pub mod beaver;
pub mod config;
pub mod cost;
pub mod engine;
pub mod field;
pub mod fl;
pub mod metrics;
pub mod mpc;
pub mod poly;
pub mod protocol;
pub mod quant;
pub mod runtime;
pub mod security;
pub mod service;
pub mod shamir;
pub mod sharing;

pub mod util;

pub use engine::{
    AdmissionError, AggScheduler, AggSession, Engine, PipelinedEngine, QosPolicy, RoundEngine,
};
pub use field::Fp;
pub use poly::{MvPolynomial, TiePolicy};
pub use service::{AggFrontend, ServiceClient, ServiceServer};

