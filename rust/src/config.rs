//! Experiment configuration: JSON-backed configs + the paper's presets
//! (Figs. 2–5, Table VI hyperparameters).
//!
//! The launcher (`hisafe train --preset fig4a` or `--config path.json`)
//! resolves a [`ExperimentConfig`], which fully determines a training run
//! (dataset, split, participants, aggregator, seeds).

use crate::fl::data::{DataKind, Partition};
use crate::poly::TiePolicy;
use crate::protocol::HiSafeConfig;
use crate::util::json::{self, Json};

/// Aggregator specification (string-friendly mirror of
/// [`crate::fl::trainer::Aggregator`], resolved at run time).
#[derive(Debug, Clone, PartialEq)]
pub enum AggSpec {
    HiSafe { ell: usize, intra: TiePolicy, inter: TiePolicy, precision: u8 },
    PlainMv { policy: TiePolicy },
    DpSign { clip: f64, sigma: f64 },
    MaskedSum,
    FedAvg,
}

/// A complete experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DataKind,
    pub partition: Partition,
    /// Total users `N`.
    pub n_users: usize,
    /// Participants per round `n = C·N`.
    pub participants: usize,
    pub rounds: usize,
    pub lr: f64,
    pub batch_size: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub eval_every: usize,
    /// Seeds for independent trials (paper: 3 trials).
    pub seeds: Vec<u64>,
    pub agg: AggSpec,
    /// Model: "linear" or "mlp_<hidden>".
    pub model: String,
}

impl ExperimentConfig {
    /// Resolve the aggregator into the trainer's enum.
    pub fn aggregator(&self) -> crate::fl::trainer::Aggregator {
        use crate::fl::trainer::Aggregator as A;
        match &self.agg {
            AggSpec::HiSafe { ell, intra, inter, precision } => A::HiSafe(HiSafeConfig {
                n: self.participants,
                ell: *ell,
                intra: *intra,
                inter: *inter,
                sparse: false,
                precision: *precision,
            }),
            AggSpec::PlainMv { policy } => A::PlainMv(*policy),
            AggSpec::DpSign { clip, sigma } => A::DpSign { clip: *clip, sigma: *sigma },
            AggSpec::MaskedSum => A::MaskedSum,
            AggSpec::FedAvg => A::FedAvg,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.clone())
            .set("dataset", self.dataset.name())
            .set("partition", self.partition.name())
            .set("n_users", self.n_users)
            .set("participants", self.participants)
            .set("rounds", self.rounds)
            .set("lr", self.lr)
            .set("batch_size", self.batch_size)
            .set("n_train", self.n_train)
            .set("n_test", self.n_test)
            .set("eval_every", self.eval_every)
            .set("seeds", self.seeds.clone().into_iter().collect::<Vec<u64>>())
            .set("model", self.model.clone());
        let mut a = Json::obj();
        match &self.agg {
            AggSpec::HiSafe { ell, intra, inter, precision } => {
                a.set("kind", "hisafe")
                    .set("ell", *ell)
                    .set("intra", intra.name())
                    .set("inter", inter.name());
                // Omitted when 2 so legacy sign-vote configs serialize unchanged.
                if *precision != 2 {
                    a.set("precision", *precision as usize);
                }
            }
            AggSpec::PlainMv { policy } => {
                a.set("kind", "plain_mv").set("policy", policy.name());
            }
            AggSpec::DpSign { clip, sigma } => {
                a.set("kind", "dp_sign").set("clip", *clip).set("sigma", *sigma);
            }
            AggSpec::MaskedSum => {
                a.set("kind", "masked_sum");
            }
            AggSpec::FedAvg => {
                a.set("kind", "fedavg");
            }
        }
        j.set("agg", a);
        j
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        let get_str = |k: &str| -> Result<&str, String> {
            j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("missing/invalid string field '{k}'"))
        };
        let get_usize = |k: &str, dflt: usize| -> Result<usize, String> {
            match j.get(k) {
                None => Ok(dflt),
                Some(v) => v.as_usize().ok_or_else(|| format!("field '{k}' must be usize")),
            }
        };
        let agg_j = j.get("agg").ok_or("missing 'agg'")?;
        let kind = agg_j.get("kind").and_then(Json::as_str).ok_or("missing agg.kind")?;
        let tie = |key: &str| -> Result<TiePolicy, String> {
            let s = agg_j.get(key).and_then(Json::as_str).unwrap_or("one_bit");
            TiePolicy::from_name(s).ok_or_else(|| format!("bad tie policy '{s}'"))
        };
        let agg = match kind {
            "hisafe" => {
                let precision = match agg_j.get("precision") {
                    None => 2,
                    Some(v) => {
                        let q = v.as_usize().ok_or("agg.precision must be an integer")?;
                        u8::try_from(q).map_err(|_| "agg.precision out of range".to_string())?
                    }
                };
                crate::quant::check_precision(precision)
                    .map_err(|e| format!("agg.precision: {e}"))?;
                AggSpec::HiSafe {
                    ell: agg_j.get("ell").and_then(Json::as_usize).ok_or("missing agg.ell")?,
                    intra: tie("intra")?,
                    inter: tie("inter")?,
                    precision,
                }
            }
            "plain_mv" => AggSpec::PlainMv { policy: tie("policy")? },
            "dp_sign" => AggSpec::DpSign {
                clip: agg_j.get("clip").and_then(Json::as_f64).unwrap_or(1.0),
                sigma: agg_j.get("sigma").and_then(Json::as_f64).unwrap_or(1.0),
            },
            "masked_sum" => AggSpec::MaskedSum,
            "fedavg" => AggSpec::FedAvg,
            other => return Err(format!("unknown aggregator kind '{other}'")),
        };
        let seeds = match j.get("seeds") {
            None => vec![0, 1, 2],
            Some(Json::Arr(v)) => v
                .iter()
                .map(|x| x.as_u64().ok_or_else(|| "seeds must be u64".to_string()))
                .collect::<Result<Vec<_>, _>>()?,
            Some(_) => return Err("'seeds' must be an array".into()),
        };
        Ok(ExperimentConfig {
            name: get_str("name")?.to_string(),
            dataset: DataKind::from_name(get_str("dataset")?)
                .ok_or_else(|| format!("unknown dataset '{}'", get_str("dataset").unwrap()))?,
            partition: Partition::from_name(get_str("partition")?)
                .ok_or_else(|| format!("unknown partition '{}'", get_str("partition").unwrap()))?,
            n_users: get_usize("n_users", 100)?,
            participants: get_usize("participants", 24)?,
            rounds: get_usize("rounds", 150)?,
            lr: j.get("lr").and_then(Json::as_f64).unwrap_or(0.005),
            batch_size: get_usize("batch_size", 100)?,
            n_train: get_usize("n_train", 6000)?,
            n_test: get_usize("n_test", 1000)?,
            eval_every: get_usize("eval_every", 5)?,
            seeds,
            agg,
            model: j.get("model").and_then(Json::as_str).unwrap_or("linear").to_string(),
        })
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

/// The paper's figure presets. Hyperparameters follow Table VI (lr 0.001
/// MNIST / 0.005 FMNIST / 0.0001 CIFAR, batch 100, 1 local epoch);
/// dataset sizes are scaled down ~10× (6k train) so every figure
/// regenerates in minutes on CPU — curves are about *relative* behaviour
/// of tie policies/subgrouping, preserved under scaling.
pub fn preset(name: &str) -> Option<ExperimentConfig> {
    let base = |name: &str, dataset: DataKind, partition: Partition, n: usize,
                lr: f64, intra: TiePolicy| ExperimentConfig {
        name: name.to_string(),
        dataset,
        partition,
        n_users: 100,
        participants: n,
        rounds: 150,
        lr,
        batch_size: 100,
        n_train: 6000,
        n_test: 1000,
        eval_every: 5,
        seeds: vec![0, 1, 2],
        agg: AggSpec::HiSafe {
            // ℓ chosen so n₁ = n/ℓ is EVEN: intra-subgroup ties are only
            // possible for even n₁ (odd n₁ makes the 1-bit and 2-bit
            // policies coincide — Table III), and the figures compare the
            // two policies. n=24 → ℓ=6 (n₁=4); n=12 → ℓ=3 (n₁=4).
            ell: if n == 24 { 6 } else { 3 },
            intra,
            inter: TiePolicy::OneBit,
            precision: 2,
        },
        model: "linear".to_string(),
    };
    use DataKind::*;
    use Partition::*;
    use TiePolicy::*;
    Some(match name {
        // Fig. 2: FMNIST n=24 non-IID, 1-bit vs 2-bit intra ties.
        "fig2a" => base("fig2a", FmnistLike, TwoClass, 24, 0.005, OneBit),
        "fig2b" => base("fig2b", FmnistLike, TwoClass, 24, 0.005, TwoBit),
        // Fig. 3: MNIST IID n=12.
        "fig3a" => base("fig3a", MnistLike, Iid, 12, 0.001, OneBit),
        "fig3b" => base("fig3b", MnistLike, Iid, 12, 0.001, TwoBit),
        // Fig. 4: FMNIST non-IID n=24 (same family as fig2, kept separate
        // to mirror the paper's figure numbering).
        "fig4a" => base("fig4a", FmnistLike, TwoClass, 24, 0.005, OneBit),
        "fig4b" => base("fig4b", FmnistLike, TwoClass, 24, 0.005, TwoBit),
        // Fig. 5: CIFAR non-IID n=24 (MLP head; lr from Table VI).
        // Fig. 5 note: Table VI's CIFAR lr (0.0001) is tuned for the
        // paper's CNN on real CIFAR; on the synthetic analogue + MLP it
        // moves parameters too little to learn in 200 rounds, so we use
        // 0.001 (documented in EXPERIMENTS.md §Substitutions).
        "fig5a" => {
            let mut c = base("fig5a", CifarLike, TwoClass, 24, 0.001, OneBit);
            c.model = "mlp_32".to_string();
            c.rounds = 150;
            c.n_train = 4000;
            c.eval_every = 10;
            c
        }
        "fig5b" => {
            let mut c = base("fig5b", CifarLike, TwoClass, 24, 0.001, TwoBit);
            c.model = "mlp_32".to_string();
            c.rounds = 150;
            c.n_train = 4000;
            c.eval_every = 10;
            c
        }
        // Baseline presets for Table-I style comparisons.
        "baseline_plain" => {
            let mut c = base("baseline_plain", FmnistLike, TwoClass, 24, 0.005, OneBit);
            c.agg = AggSpec::PlainMv { policy: OneBit };
            c
        }
        "baseline_dp" => {
            let mut c = base("baseline_dp", FmnistLike, TwoClass, 24, 0.005, OneBit);
            c.agg = AggSpec::DpSign { clip: 1.0, sigma: 0.05 };
            c
        }
        "baseline_masking" => {
            let mut c = base("baseline_masking", FmnistLike, TwoClass, 24, 0.005, OneBit);
            c.agg = AggSpec::MaskedSum;
            c
        }
        "baseline_fedavg" => {
            // float-gradient averaging needs a ~100× larger step than the
            // ±1 sign update to move comparably per round
            let mut c = base("baseline_fedavg", FmnistLike, TwoClass, 24, 0.5, OneBit);
            c.agg = AggSpec::FedAvg;
            c
        }
        _ => return None,
    })
}

/// Names of all built-in presets.
pub fn preset_names() -> Vec<&'static str> {
    vec![
        "fig2a", "fig2b", "fig3a", "fig3b", "fig4a", "fig4b", "fig5a", "fig5b",
        "baseline_plain", "baseline_dp", "baseline_masking", "baseline_fedavg",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_all_resolve() {
        for name in preset_names() {
            let c = preset(name).unwrap_or_else(|| panic!("preset {name}"));
            assert_eq!(c.name, name);
            // aggregator resolves without panicking and n matches
            let _ = c.aggregator();
            assert!(c.participants <= c.n_users);
            if let AggSpec::HiSafe { ell, .. } = c.agg {
                assert_eq!(c.participants % ell, 0, "{name}: ℓ ∤ n");
            }
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn json_roundtrip_all_presets() {
        for name in preset_names() {
            let c = preset(name).unwrap();
            let j = c.to_json();
            let text = j.to_string_pretty();
            let back = ExperimentConfig::from_json(&crate::util::json::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back, c, "{name} roundtrip");
        }
    }

    #[test]
    fn from_json_rejects_bad_configs() {
        let bad = crate::util::json::parse(
            r#"{"name":"x","dataset":"mnist_like","partition":"iid","agg":{"kind":"warp"}}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
        let missing_agg = crate::util::json::parse(
            r#"{"name":"x","dataset":"mnist_like","partition":"iid"}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&missing_agg).is_err());
    }

    #[test]
    fn table6_learning_rates() {
        assert_eq!(preset("fig3a").unwrap().lr, 0.001); // MNIST
        assert_eq!(preset("fig2a").unwrap().lr, 0.005); // FMNIST
        assert_eq!(preset("fig5a").unwrap().lr, 0.001); // CIFAR (see preset note)
        assert_eq!(preset("fig2a").unwrap().batch_size, 100);
    }
}
