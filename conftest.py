"""Repo-root pytest config: make `pytest python/tests/` work from the root
(the compile package lives under python/), and skip — rather than fail —
test modules whose optional dependencies (jax, hypothesis) are absent.
The CI python job relies on this: a CPU-only runner without JAX must
still exit green (python/tests/test_env_gating.py is dependency-free and
guarantees a non-empty collection, since pytest exits 5 on zero tests).

Gating is derived from each test module's imports rather than a
hand-maintained list, so future JAX/hypothesis test files are covered
automatically.
"""

import glob
import importlib.util
import os
import re
import sys

_ROOT = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_ROOT, "python"))


def _have(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ValueError):
        return False


def _needs(src: str, mod: str) -> bool:
    return re.search(rf"^\s*(import|from)\s+{mod}\b", src, re.M) is not None


def _gated_modules():
    """Test modules (conftest-relative paths) whose imports are missing."""
    ignored = []
    for path in sorted(glob.glob(os.path.join(_ROOT, "python", "tests", "test_*.py"))):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        # `compile` (python/compile) is the in-repo JAX/Pallas package:
        # importing it pulls in jax transitively.
        needs_jax = _needs(src, "jax") or _needs(src, "compile")
        missing = (needs_jax and not _have("jax")) or (
            _needs(src, "hypothesis") and not _have("hypothesis")
        )
        if missing:
            ignored.append(os.path.relpath(path, _ROOT))
    return ignored


# Modules ignored at collection time (paths relative to this conftest).
collect_ignore = _gated_modules()
